//! Pipeline-parallel sharded execution (PERF.md §12): a
//! [`PipelineCoordinator`] in front of N [`ShardWorker`] stages, each
//! owning a contiguous layer range ([`ShardSpec::Range`] — the same
//! split `serve-artifact --shard i/n` cold-starts), streaming hidden
//! states shard→shard over a [`ShardTransport`] ring with K in-flight
//! micro-batches: shard i computes micro-batch m while shard i+1
//! computes m−1, the classic bubble-fill.
//!
//! Like `serve/churn.rs` this is an XLA-free harness for the system
//! layer around the executables: the per-layer transform is a
//! deterministic, KV-coupled attention-lite stand-in (write k/v at the
//! row's position, read the running mean of v, mix with a per-layer
//! digest). What it exercises for real:
//!   * the frame wire format + integrity checks ([`ActivationFrame`]);
//!   * per-shard cold start through [`ArtifactReader::load_shard`]
//!     (each worker opens its OWN reader and reads only its slice —
//!     cold-start bytes are measured per shard);
//!   * slot-strided per-shard KV: each worker's [`SlotKv`] holds only
//!     its layers, so per-shard KV memory is ~1/N of the total;
//!   * admission/lease accounting ([`plan_admissions`],
//!     [`KvBlockManager`]) and the queue/decode latency split.
//!
//! Determinism contract (property-tested in `tests/prop_pipeline.rs`):
//! a request's tokens depend only on its own prompt and its own slot's
//! KV, and every layer sees rows in the same order regardless of the
//! partition — so output tokens and per-request completion steps are
//! BIT-IDENTICAL across 1/2/4 shards and any micro-batch count. The
//! single-process baseline is the same engine at `shards == 1`.
//!
//! Scheduling/time model: under a virtual clock, one decode round costs
//! the same total work regardless of the partition — each (shard,
//! micro-batch) chunk costs τ = [`VIRTUAL_MS_PER_STEP`]/(N·F), a round's
//! makespan is (N+F−1)·τ, and the per-round bubble (makespan minus the
//! ideal F·τ) is (N−1)·τ. At N=1, F=1 this degenerates to exactly one
//! engine step. Per-shard busy/wait/idle lanes and
//! `pipeline_bubble_ms` are accumulated from this model (deterministic
//! under either clock); frame/byte counters are real transport counts.

use super::engine::{plan_admissions, Completion, VIRTUAL_MS_PER_STEP};
use super::kvcache::{KvBlockManager, KvConfig};
use super::kvstate::{KvLayout, SlotKv};
use super::metrics::{CompletionStat, ServeMetrics, ShardLane};
use super::trace::{Clock, QueuedRequest, Request};
use super::transport::{
    ActivationFrame, LocalPipe, ShardTransport, SocketTransport, TcpTransport, FRAME_DECODE,
    FRAME_PREFILL, FRAME_SHUTDOWN,
};
use crate::quant::reader::{ArtifactReader, ShardSpec};
use anyhow::{anyhow, bail, ensure, Result};
use std::collections::VecDeque;
use std::path::PathBuf;
use std::thread::JoinHandle;

/// Per-layer digest the attention-lite transform mixes in — the
/// pipeline analogue of a layer's weights. `coef` has `dim` entries.
#[derive(Clone, Debug)]
pub struct LayerDigest {
    pub coef: Vec<f32>,
}

/// Fold a dequantized dense plane into a `dim`-wide digest. Pure
/// per-layer computation in index order, so it is identical no matter
/// which shard loads the layer.
pub fn digest_plane(data: &[f32], dim: usize) -> LayerDigest {
    let mut acc = vec![0.0f32; dim.max(1)];
    for (i, &v) in data.iter().enumerate() {
        acc[i % dim.max(1)] += v;
    }
    let scale = if data.is_empty() { 1.0 } else { dim.max(1) as f32 / data.len() as f32 };
    let coef = acc.iter().map(|&a| squash(a * scale)).collect();
    LayerDigest { coef }
}

/// The full layer stack the ring executes, plus its hidden width.
#[derive(Clone, Debug)]
pub struct PipelineModel {
    pub dim: usize,
    pub layers: Vec<LayerDigest>,
}

impl PipelineModel {
    /// Deterministic synthetic model (the churn-style XLA-free mode).
    pub fn synthetic(layers: usize, dim: usize, seed: u64) -> PipelineModel {
        let mut rng = crate::util::prng::Rng::from_stream(seed, "pipeline-model");
        let layers = (0..layers)
            .map(|_| LayerDigest {
                coef: (0..dim).map(|_| rng.normal_f32() * 0.5).collect(),
            })
            .collect();
        PipelineModel { dim, layers }
    }
}

/// Where a shard worker gets its layer slice from.
enum ShardModel {
    /// Pre-sliced digests (synthetic mode).
    Digests(Vec<LayerDigest>),
    /// Cold-start the slice through a per-worker [`ArtifactReader`]:
    /// open the file, read ONLY this shard's plane bytes, dequantize,
    /// digest.
    Artifact { path: PathBuf, index: usize, count: usize },
}

/// What a shard worker reports back at shutdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerReport {
    pub layers: usize,
    /// bytes the worker's own `ArtifactReader` pulled off disk for its
    /// slice (0 in synthetic mode)
    pub cold_start_bytes: u64,
    /// resident KV bytes for this shard's slice: `slot_kv_bytes × batch`
    pub kv_bytes: u64,
    /// host bytes admissions moved into this shard's `SlotKv`
    pub kv_admit_bytes: u64,
    pub frames_sent: u64,
    pub bytes_sent: u64,
}

#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub shards: usize,
    /// requested micro-batches in flight; the effective count is
    /// `ceil(batch / ceil(batch / K))` (contiguous slot ranges)
    pub micro_batches: usize,
    pub batch: usize,
    pub seq: usize,
    pub heads: usize,
    pub d_head: usize,
    pub vocab: usize,
    /// total layer count (synthetic mode; artifact mode uses the file's)
    pub layers: usize,
    pub seed: u64,
    /// ring over [`SocketTransport`] instead of [`LocalPipe`]
    pub socket: bool,
    /// ring over [`TcpTransport`] — loopback pairs by default, or
    /// multi-host rendezvous addresses via `HIGGS_SHARD_TCP`
    pub tcp: bool,
    pub virtual_clock: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            shards: 2,
            micro_batches: 1,
            batch: 4,
            seq: 32,
            heads: 2,
            d_head: 4,
            vocab: 97,
            layers: 4,
            seed: 0xC0FFEE,
            socket: false,
            tcp: false,
            virtual_clock: true,
        }
    }
}

impl PipelineConfig {
    pub fn dim(&self) -> usize {
        self.heads * self.d_head
    }
}

/// The model source for a pipeline run.
pub enum PipelineSource {
    /// `cfg.layers` synthetic digests from `cfg.seed`.
    Synthetic,
    /// Split the artifact's layer stack across the shards; each worker
    /// cold-starts its own slice through its own reader.
    Artifact(PathBuf),
}

/// One token produced during a tick, in production order — the
/// streaming seam the serving daemon consumes. Recording is opt-in
/// (`set_token_recording`) so batch runs pay nothing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TokenEvent {
    pub id: u64,
    /// 0 is the admission token (end of prefill)
    pub index: usize,
    pub token: i32,
}

enum PipeSlot {
    Idle,
    Active {
        req: Request,
        pos: usize,
        generated: Vec<i32>,
        last_token: i32,
        enqueued_ms: f64,
        admitted_ms: f64,
    },
}

/// Everything a finished run reports (the churn-report analogue).
pub struct PipelineReport {
    pub metrics: ServeMetrics,
    /// completions sorted by request id — the bit-identity surface
    pub completions: Vec<Completion>,
    /// (request id, decode round) admission order
    pub admission_steps: Vec<(u64, u64)>,
    pub completion_steps: Vec<(u64, u64)>,
    pub steps: u64,
    pub shards: usize,
    /// effective micro-batches in flight (F)
    pub micro_batches: usize,
    pub worker_reports: Vec<WorkerReport>,
    pub coord_frames_sent: u64,
    pub coord_bytes_sent: u64,
    /// KV blocks still leased at the end (0 = no leak)
    pub blocks_leaked: usize,
}

impl PipelineReport {
    pub fn cold_start_bytes(&self) -> u64 {
        self.worker_reports.iter().map(|w| w.cold_start_bytes).sum()
    }

    pub fn total_frames(&self) -> u64 {
        self.coord_frames_sent + self.worker_reports.iter().map(|w| w.frames_sent).sum::<u64>()
    }

    pub fn total_wire_bytes(&self) -> u64 {
        self.coord_bytes_sent + self.worker_reports.iter().map(|w| w.bytes_sent).sum::<u64>()
    }
}

pub struct PipelineCoordinator {
    cfg: PipelineConfig,
    dim: usize,
    /// effective micro-batch count F and the contiguous range width
    mb_count: usize,
    mb_size: usize,
    down: Box<dyn ShardTransport + Send>,
    up: Box<dyn ShardTransport + Send>,
    workers: Vec<JoinHandle<Result<WorkerReport>>>,
    slots: Vec<PipeSlot>,
    queue: VecDeque<QueuedRequest>,
    kv_manager: KvBlockManager,
    pub metrics: ServeMetrics,
    clock: Clock,
    start_ms: f64,
    blocked_since: Option<f64>,
    step: u64,
    completions: Vec<Completion>,
    admission_steps: Vec<(u64, u64)>,
    completion_steps: Vec<(u64, u64)>,
    record_tokens: bool,
    token_events: Vec<TokenEvent>,
}

impl PipelineCoordinator {
    /// Build the ring and spawn the shard workers (threads via
    /// `util::pool::spawn_worker`; real processes would speak the same
    /// socket protocol — multi-host is future work, PERF.md §12).
    pub fn new(cfg: PipelineConfig, source: &PipelineSource) -> Result<PipelineCoordinator> {
        ensure!(cfg.shards >= 1, "pipeline needs at least one shard");
        ensure!(cfg.batch >= 1 && cfg.batch <= 64, "batch must be in 1..=64 (active bitmap)");
        ensure!(cfg.micro_batches >= 1, "micro-batch count must be >= 1");
        ensure!(cfg.dim() >= 1, "hidden width heads*d_head must be >= 1");
        ensure!(!(cfg.socket && cfg.tcp), "pick one of --socket / --tcp, not both");
        let dim = cfg.dim();
        // resolve each shard's model slice
        let (shard_models, total_layers) = match source {
            PipelineSource::Synthetic => {
                let model = PipelineModel::synthetic(cfg.layers, dim, cfg.seed);
                let total = model.layers.len();
                let slices = (0..cfg.shards)
                    .map(|i| {
                        let spec = ShardSpec::Range { index: i, count: cfg.shards };
                        let digests = spec
                            .layer_indices(total)
                            .into_iter()
                            .map(|l| model.layers[l].clone())
                            .collect();
                        ShardModel::Digests(digests)
                    })
                    .collect::<Vec<_>>();
                (slices, total)
            }
            PipelineSource::Artifact(path) => {
                let reader = ArtifactReader::open(path)?;
                let total = reader.entries().len();
                let slices = (0..cfg.shards)
                    .map(|i| ShardModel::Artifact {
                        path: path.clone(),
                        index: i,
                        count: cfg.shards,
                    })
                    .collect();
                (slices, total)
            }
        };
        ensure!(
            total_layers >= cfg.shards,
            "cannot split {total_layers} layers across {} shards",
            cfg.shards
        );
        // contiguous micro-batch ranges: F = ceil(B / ceil(B / K))
        let mb_size = cfg.batch.div_ceil(cfg.micro_batches.min(cfg.batch));
        let mb_count = cfg.batch.div_ceil(mb_size);
        // the ring: stage 0 is the coordinator, stages 1..=N the shard
        // workers; link j carries stage j → stage j+1 (mod N+1)
        let n = cfg.shards;
        let mut send_ends: Vec<Option<Box<dyn ShardTransport + Send>>> = Vec::new();
        let mut recv_ends: Vec<Option<Box<dyn ShardTransport + Send>>> = Vec::new();
        for link in 0..=n {
            let (s, r): (Box<dyn ShardTransport + Send>, Box<dyn ShardTransport + Send>) =
                if cfg.tcp {
                    let (a, b) = tcp_link(link)?;
                    (Box::new(a), Box::new(b))
                } else if cfg.socket {
                    let (a, b) = socket_link(link)?;
                    (Box::new(a), Box::new(b))
                } else {
                    let (a, b) = LocalPipe::pair();
                    (Box::new(a), Box::new(b))
                };
            send_ends.push(Some(s));
            recv_ends.push(Some(r));
        }
        let down = send_ends[0].take().ok_or_else(|| anyhow!("ring link 0 missing"))?;
        let up = recv_ends[n].take().ok_or_else(|| anyhow!("ring link {n} missing"))?;
        let mut workers = Vec::with_capacity(n);
        for (i, model) in shard_models.into_iter().enumerate() {
            let w_up = recv_ends[i].take().ok_or_else(|| anyhow!("ring link {i} missing"))?;
            let w_down =
                send_ends[i + 1].take().ok_or_else(|| anyhow!("ring link {} missing", i + 1))?;
            let wcfg = WorkerConfig {
                dim,
                batch: cfg.batch,
                seq: cfg.seq,
                heads: cfg.heads,
                d_head: cfg.d_head,
                mb_size,
            };
            workers.push(crate::util::pool::spawn_worker(
                &format!("shard-{i}"),
                move || ShardWorker::run(model, wcfg, w_up, w_down),
            ));
        }
        let clock = if cfg.virtual_clock { Clock::virtual_at(0.0) } else { Clock::wall() };
        let start_ms = clock.now_ms();
        let kv_manager = KvBlockManager::new(KvConfig::for_model(cfg.seq, cfg.batch, 16));
        let slots = (0..cfg.batch).map(|_| PipeSlot::Idle).collect();
        Ok(PipelineCoordinator {
            dim,
            mb_count,
            mb_size,
            down,
            up,
            workers,
            slots,
            queue: VecDeque::new(),
            kv_manager,
            metrics: ServeMetrics::default(),
            clock,
            start_ms,
            blocked_since: None,
            step: 0,
            completions: Vec::new(),
            admission_steps: Vec::new(),
            completion_steps: Vec::new(),
            record_tokens: false,
            token_events: Vec::new(),
            cfg,
        })
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(QueuedRequest::at(req, self.clock.now_ms()));
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn active_slots(&self) -> usize {
        self.slots.iter().filter(|s| matches!(s, PipeSlot::Active { .. })).count()
    }

    pub fn now_ms(&self) -> f64 {
        self.clock.now_ms()
    }

    /// Current decode round (the arrival index `run_arrivals` keys on).
    pub fn step(&self) -> u64 {
        self.step
    }

    /// Effective micro-batches in flight.
    pub fn micro_batches(&self) -> usize {
        self.mb_count
    }

    /// Opt into per-token [`TokenEvent`] recording (the daemon's
    /// streaming seam). Off by default — batch runs pay nothing.
    pub fn set_token_recording(&mut self, on: bool) {
        self.record_tokens = on;
    }

    /// Drain the tokens produced since the last call, in production
    /// order. Empty unless `set_token_recording(true)` was called.
    pub fn take_token_events(&mut self) -> Vec<TokenEvent> {
        std::mem::take(&mut self.token_events)
    }

    /// Push raw bytes down the coordinator → shard-0 link — the
    /// corruption seam for tests (a flipped byte must surface as an
    /// `Err` + `internal_errors`, never a panic).
    pub fn inject_raw_downstream(&self, bytes: Vec<u8>) -> Result<()> {
        self.down.send_raw(bytes)
    }

    /// One coordinator iteration: admit what fits (one prefill ring
    /// traversal per admitted request), then run one decode round with
    /// F micro-batch frames in flight. Errors are counted in
    /// `internal_errors` and propagated, mirroring the engine.
    pub fn tick(&mut self) -> Result<Vec<Completion>> {
        let r = self.tick_impl();
        if r.is_err() {
            self.metrics.internal_errors += 1;
        }
        r
    }

    fn tick_impl(&mut self) -> Result<Vec<Completion>> {
        self.admit()?;
        if self.active_slots() == 0 {
            return Ok(Vec::new());
        }
        self.decode_round()
    }

    fn admit(&mut self) -> Result<()> {
        self.metrics.queue_peak = self.metrics.queue_peak.max(self.queue.len());
        if self.queue.is_empty() {
            self.note_unblocked();
            return Ok(());
        }
        let now_ms = self.clock.now_ms();
        let idle: Vec<usize> = (0..self.cfg.batch)
            .filter(|&b| matches!(self.slots[b], PipeSlot::Idle))
            .collect();
        if idle.is_empty() {
            self.blocked_since.get_or_insert(now_ms);
            return Ok(());
        }
        let newly = plan_admissions(
            &mut self.queue,
            &mut self.kv_manager,
            &idle,
            self.cfg.seq,
            &mut self.metrics,
        )?;
        if newly.is_empty() {
            if self.queue.is_empty() {
                self.note_unblocked();
            } else {
                self.blocked_since.get_or_insert(now_ms);
            }
            return Ok(());
        }
        self.note_unblocked();
        self.metrics.prefill_calls += 1;
        for (b, plen, qr) in newly {
            let mut data = vec![0.0f32; plen * self.dim];
            for (t, row) in data.chunks_exact_mut(self.dim).enumerate() {
                let tok = qr.req.prompt.get(t).copied().unwrap_or(0);
                embed_token(tok, row);
            }
            let frame = ActivationFrame {
                kind: FRAME_PREFILL,
                mb: b as u32,
                step: self.step,
                rows: plen as u32,
                cols: self.dim as u32,
                active: 0,
                pos: (0..plen as u32).collect(),
                data,
            };
            self.down.send(&frame)?;
            let out = self.up.recv()?;
            ensure!(
                out.kind == FRAME_PREFILL && out.mb == b as u32 && out.rows == plen as u32,
                "prefill echo mismatch: slot {b} plen {plen}, got kind {} mb {} rows {}",
                out.kind,
                out.mb,
                out.rows
            );
            let last = out
                .data
                .get((plen - 1) * self.dim..plen * self.dim)
                .ok_or_else(|| anyhow!("prefill echo shorter than its header"))?;
            let first = sample_token(last, self.cfg.vocab);
            if self.record_tokens {
                self.token_events.push(TokenEvent { id: qr.req.id, index: 0, token: first });
            }
            self.admission_steps.push((qr.req.id, self.step));
            self.slots[b] = PipeSlot::Active {
                pos: plen,
                generated: vec![first],
                last_token: first,
                enqueued_ms: qr.enqueued_ms,
                admitted_ms: self.clock.now_ms(),
                req: qr.req,
            };
        }
        Ok(())
    }

    fn decode_round(&mut self) -> Result<Vec<Completion>> {
        let dim = self.dim;
        // fan the batch out as F micro-batch frames, all in flight
        for m in 0..self.mb_count {
            let base = m * self.mb_size;
            let rows = self.mb_size.min(self.cfg.batch - base);
            let mut active = 0u64;
            let mut pos = vec![0u32; rows];
            let mut data = vec![0.0f32; rows * dim];
            for r in 0..rows {
                if let PipeSlot::Active { pos: p, last_token, .. } = &self.slots[base + r] {
                    active |= 1 << r;
                    pos[r] = *p as u32;
                    if let Some(row) = data.get_mut(r * dim..(r + 1) * dim) {
                        embed_token(*last_token, row);
                    }
                }
            }
            let frame = ActivationFrame {
                kind: FRAME_DECODE,
                mb: m as u32,
                step: self.step,
                rows: rows as u32,
                cols: dim as u32,
                active,
                pos,
                data,
            };
            self.down.send(&frame)?;
        }
        // virtual-time pipeline model (see module docs): τ per chunk,
        // (N+F−1)·τ makespan, (N−1)·τ bubble per round
        let n = self.cfg.shards;
        let f = self.mb_count;
        let tau = VIRTUAL_MS_PER_STEP / (n * f) as f64;
        self.clock.advance((n + f - 1) as f64 * tau);
        if self.metrics.shard_lanes.len() != n {
            self.metrics.shard_lanes = vec![ShardLane::default(); n];
        }
        for (i, lane) in self.metrics.shard_lanes.iter_mut().enumerate() {
            lane.busy_ms += f as f64 * tau;
            lane.wait_ms += i as f64 * tau;
            lane.idle_ms += (n - 1 - i) as f64 * tau;
        }
        self.metrics.pipeline_bubble_ms += (n - 1) as f64 * tau;
        self.metrics.decode_steps += 1;
        self.step += 1;

        // drain the F result frames (ring links are FIFO)
        let mut done = Vec::new();
        for m in 0..self.mb_count {
            let out = self.up.recv()?;
            ensure!(
                out.kind == FRAME_DECODE && out.mb == m as u32,
                "decode echo mismatch: wanted micro-batch {m}, got kind {} mb {}",
                out.kind,
                out.mb
            );
            let base = m as usize * self.mb_size;
            let rows = out.rows as usize;
            for r in 0..rows {
                if out.active & (1 << r) == 0 {
                    continue;
                }
                let row = out
                    .data
                    .get(r * dim..(r + 1) * dim)
                    .ok_or_else(|| anyhow!("decode echo shorter than its header"))?;
                let next = sample_token(row, self.cfg.vocab);
                let b = base + r;
                let slot = self
                    .slots
                    .get_mut(b)
                    .ok_or_else(|| anyhow!("decode echo names slot {b} beyond batch"))?;
                if let PipeSlot::Active {
                    pos,
                    generated,
                    last_token,
                    req,
                    enqueued_ms,
                    admitted_ms,
                } = slot
                {
                    *pos += 1;
                    generated.push(next);
                    *last_token = next;
                    if self.record_tokens {
                        self.token_events.push(TokenEvent {
                            id: req.id,
                            index: generated.len() - 1,
                            token: next,
                        });
                    }
                    self.kv_manager.append_token(req.id)?;
                    let capacity_hit = *pos + 1 >= self.cfg.seq;
                    if generated.len() >= req.max_new || capacity_hit {
                        let now_ms = self.clock.now_ms();
                        let latency_ms = now_ms - *enqueued_ms;
                        let queue_ms = *admitted_ms - *enqueued_ms;
                        let decode_ms = now_ms - *admitted_ms;
                        let c = Completion {
                            id: req.id,
                            tokens: generated.clone(),
                            latency_ms,
                            queue_ms,
                            decode_ms,
                            prompt_len: req.prompt.len(),
                        };
                        self.metrics.completions.push(CompletionStat {
                            latency_ms,
                            queue_ms,
                            decode_ms,
                            generated: generated.len(),
                            prompt_len: req.prompt.len(),
                        });
                        self.completion_steps.push((req.id, self.step));
                        self.kv_manager.release(req.id)?;
                        self.completions.push(c.clone());
                        done.push(c);
                        self.slots[b] = PipeSlot::Idle;
                    }
                }
            }
        }
        Ok(done)
    }

    fn note_unblocked(&mut self) {
        if let Some(t) = self.blocked_since.take() {
            self.metrics.admission_blocked_ms += self.clock.now_ms() - t;
        }
    }

    /// Drain the admission queue into the drop counter (safety valve
    /// for requests that can never be admitted — callers decide when
    /// the queue is hopeless; nothing is ever discarded silently).
    pub fn drop_queued(&mut self) {
        self.metrics.dropped += self.queue.len() as u64;
        self.queue.clear();
    }

    /// Open-loop driver over step-indexed arrivals (the churn format:
    /// `(arrival_step, request)`). Keying arrivals on the decode ROUND
    /// index — not clock ms — is what keeps the arrival/round
    /// interleaving, and therefore every token, identical across shard
    /// and micro-batch counts.
    pub fn run_arrivals(&mut self, arrivals: Vec<(u64, Request)>) -> Result<()> {
        let mut arrivals: VecDeque<(u64, Request)> = arrivals.into();
        loop {
            while arrivals.front().map(|(t, _)| *t <= self.step).unwrap_or(false) {
                if let Some((_, r)) = arrivals.pop_front() {
                    self.submit(r);
                }
            }
            if self.queue.is_empty() && self.active_slots() == 0 {
                match arrivals.front() {
                    Some((t, _)) => {
                        // idle: jump the round counter (and the virtual
                        // clock) to the next arrival
                        let target = (*t).max(self.step + 1);
                        self.clock.advance((target - self.step) as f64 * VIRTUAL_MS_PER_STEP);
                        self.step = target;
                        continue;
                    }
                    None => break,
                }
            }
            self.tick()?;
            if self.active_slots() == 0 && !self.queue.is_empty() {
                if arrivals.is_empty() {
                    // head request can never fit: surface, don't spin
                    log::error!(
                        "pipeline stuck: dropping {} unservable request(s)",
                        self.queue.len()
                    );
                    self.drop_queued();
                } else {
                    // let time pass toward the next arrival
                    self.clock.advance(VIRTUAL_MS_PER_STEP);
                    self.step += 1;
                }
            }
        }
        Ok(())
    }

    /// Drain the ring (one shutdown frame traverses every stage), join
    /// the workers, and fold their reports into the metrics. Worker
    /// errors are logged + counted, not panicked on.
    pub fn finish(mut self) -> Result<PipelineReport> {
        if let Err(e) = self.down.send(&ActivationFrame::shutdown()) {
            log::error!("pipeline shutdown send failed: {e}");
            self.metrics.internal_errors += 1;
        } else {
            match self.up.recv() {
                Ok(f) if f.kind == FRAME_SHUTDOWN => {}
                Ok(f) => {
                    log::error!("pipeline shutdown echoed frame kind {}", f.kind);
                    self.metrics.internal_errors += 1;
                }
                Err(e) => {
                    log::error!("pipeline shutdown echo failed: {e}");
                    self.metrics.internal_errors += 1;
                }
            }
        }
        let mut worker_reports = Vec::with_capacity(self.workers.len());
        for (i, h) in self.workers.drain(..).enumerate() {
            match h.join() {
                Ok(Ok(r)) => worker_reports.push(r),
                Ok(Err(e)) => {
                    log::error!("shard worker {i} failed: {e}");
                    self.metrics.internal_errors += 1;
                    worker_reports.push(WorkerReport::default());
                }
                Err(_) => {
                    log::error!("shard worker {i} panicked");
                    self.metrics.internal_errors += 1;
                    worker_reports.push(WorkerReport::default());
                }
            }
        }
        // lanes carry the model-based split; frames/bytes are the real
        // transport counters (shard i's lane counts its DOWNSTREAM link)
        if self.metrics.shard_lanes.len() != worker_reports.len() {
            self.metrics.shard_lanes = vec![ShardLane::default(); worker_reports.len()];
        }
        for (lane, w) in self.metrics.shard_lanes.iter_mut().zip(&worker_reports) {
            lane.frames_sent = w.frames_sent;
            lane.bytes_sent = w.bytes_sent;
        }
        self.metrics.wall_secs = (self.clock.now_ms() - self.start_ms) / 1e3;
        let mut completions = std::mem::take(&mut self.completions);
        completions.sort_by_key(|c| c.id);
        Ok(PipelineReport {
            metrics: self.metrics.clone(),
            completions,
            admission_steps: std::mem::take(&mut self.admission_steps),
            completion_steps: std::mem::take(&mut self.completion_steps),
            steps: self.step,
            shards: self.cfg.shards,
            micro_batches: self.mb_count,
            worker_reports,
            coord_frames_sent: self.down.frames_sent(),
            coord_bytes_sent: self.down.bytes_sent(),
            blocks_leaked: self.kv_manager.n_blocks() - self.kv_manager.free_blocks(),
        })
    }
}

/// Build one ring link over sockets: an anonymous `pair()` by default,
/// or a filesystem rendezvous when `HIGGS_SHARD_SOCKET` names a path
/// prefix (the seam a future multi-process launcher binds to).
fn socket_link(link: usize) -> Result<(SocketTransport, SocketTransport)> {
    let Some(path) = SocketTransport::rendezvous_path(link) else {
        return SocketTransport::pair();
    };
    let lp = path.clone();
    let listener =
        crate::util::pool::spawn_worker("shard-listen", move || SocketTransport::listen(&lp));
    let mut connected = None;
    for _ in 0..100_000 {
        match SocketTransport::connect(&path) {
            Ok(c) => {
                connected = Some(c);
                break;
            }
            Err(_) => std::thread::yield_now(),
        }
    }
    let connect_end =
        connected.ok_or_else(|| anyhow!("rendezvous connect timed out on {}", path.display()))?;
    let listen_end = listener
        .join()
        .map_err(|_| anyhow!("rendezvous listener panicked"))?
        .map_err(|e| anyhow!("rendezvous listen on {}: {e}", path.display()))?;
    // sender side holds the connect end; either end is duplex
    Ok((connect_end, listen_end))
}

/// Build one ring link over TCP: a loopback `pair()` by default, or a
/// rendezvous address when `HIGGS_SHARD_TCP` names `host:base_port`
/// (link i uses port `base_port + i` — the multi-host seam).
fn tcp_link(link: usize) -> Result<(TcpTransport, TcpTransport)> {
    let Some(addr) = TcpTransport::rendezvous_addr(link)? else {
        return TcpTransport::pair();
    };
    let la = addr.clone();
    let listener = crate::util::pool::spawn_worker("shard-listen", move || TcpTransport::listen(&la));
    let mut connected = None;
    for _ in 0..100_000 {
        match TcpTransport::connect(&addr) {
            Ok(c) => {
                connected = Some(c);
                break;
            }
            Err(_) => std::thread::yield_now(),
        }
    }
    let connect_end =
        connected.ok_or_else(|| anyhow!("rendezvous connect timed out on {addr}"))?;
    let listen_end = listener
        .join()
        .map_err(|_| anyhow!("rendezvous listener panicked"))?
        .map_err(|e| anyhow!("rendezvous listen on {addr}: {e}"))?;
    Ok((connect_end, listen_end))
}

struct WorkerConfig {
    dim: usize,
    batch: usize,
    seq: usize,
    heads: usize,
    d_head: usize,
    mb_size: usize,
}

/// One pipeline stage: cold-start the layer slice, then serve frames
/// until a shutdown traverses the ring. Holds a [`SlotKv`] covering
/// ONLY its own layers (per-shard KV memory ~1/N of the model's).
struct ShardWorker {
    cfg: WorkerConfig,
    layers: Vec<LayerDigest>,
    layout: KvLayout,
    kv: SlotKv,
}

impl ShardWorker {
    fn run(
        model: ShardModel,
        cfg: WorkerConfig,
        up: Box<dyn ShardTransport + Send>,
        down: Box<dyn ShardTransport + Send>,
    ) -> Result<WorkerReport> {
        let (layers, cold_start_bytes) = match model {
            ShardModel::Digests(d) => (d, 0u64),
            ShardModel::Artifact { path, index, count } => {
                let reader = ArtifactReader::open(&path)?;
                let slice = reader.load_shard(&ShardSpec::Range { index, count })?;
                let digests = slice
                    .layers
                    .iter()
                    .map(|l| digest_plane(&l.dequantize().data, cfg.dim))
                    .collect();
                (digests, reader.bytes_read())
            }
        };
        ensure!(!layers.is_empty(), "shard worker got an empty layer slice");
        let layout = KvLayout {
            layers: layers.len(),
            heads: cfg.heads,
            seq: cfg.seq,
            d_head: cfg.d_head,
        };
        let kv = SlotKv::new(layout, cfg.batch)?;
        let mut w = ShardWorker { cfg, layers, layout, kv };
        loop {
            let frame = up.recv()?;
            match frame.kind {
                FRAME_SHUTDOWN => {
                    down.send(&frame)?;
                    break;
                }
                FRAME_PREFILL => {
                    let out = w.prefill(frame)?;
                    down.send(&out)?;
                }
                FRAME_DECODE => {
                    let out = w.decode(frame)?;
                    down.send(&out)?;
                }
                k => bail!("shard worker got unknown frame kind {k}"),
            }
        }
        Ok(WorkerReport {
            layers: w.layers.len(),
            cold_start_bytes,
            kv_bytes: w.layout.slot_kv_bytes() * w.cfg.batch as u64,
            kv_admit_bytes: w.kv.admit_bytes,
            frames_sent: down.frames_sent(),
            bytes_sent: down.bytes_sent(),
        })
    }

    fn check_frame(&self, frame: &ActivationFrame) -> Result<()> {
        ensure!(
            frame.cols as usize == self.cfg.dim,
            "frame width {} != hidden width {}",
            frame.cols,
            self.cfg.dim
        );
        ensure!(
            frame.pos.len() == frame.rows as usize
                && frame.data.len() == frame.rows as usize * self.cfg.dim,
            "frame body inconsistent with its header"
        );
        for &p in &frame.pos {
            ensure!((p as usize) < self.cfg.seq, "KV position {p} beyond seq {}", self.cfg.seq);
        }
        Ok(())
    }

    /// Admit one slot: run the prompt rows through this shard's layers
    /// (row t sees rows 0..t's k/v, causal order), then install the
    /// slot's KV via the strided admission path.
    fn prefill(&mut self, mut frame: ActivationFrame) -> Result<ActivationFrame> {
        self.check_frame(&frame)?;
        let slot = frame.mb as usize;
        ensure!(slot < self.cfg.batch, "prefill slot {slot} beyond batch {}", self.cfg.batch);
        let dim = self.cfg.dim;
        let full = self.layout.full_elems(self.cfg.batch);
        let (mut kc, mut vc) = (vec![0.0f32; full], vec![0.0f32; full]);
        for t in 0..frame.rows as usize {
            let row = frame
                .data
                .get_mut(t * dim..(t + 1) * dim)
                .ok_or_else(|| anyhow!("prefill frame shorter than its header"))?;
            for (l, digest) in self.layers.iter().enumerate() {
                transform_row(row, t, digest, l, slot, &self.layout, self.cfg.batch, &mut kc, &mut vc);
            }
        }
        self.kv.admit_from_full(&[slot], &kc, &vc)?;
        Ok(frame)
    }

    /// One decode micro-batch: read-modify-write this shard's KV for
    /// the frame's live rows.
    fn decode(&mut self, mut frame: ActivationFrame) -> Result<ActivationFrame> {
        self.check_frame(&frame)?;
        let dim = self.cfg.dim;
        let base = frame.mb as usize * self.cfg.mb_size;
        ensure!(
            base + frame.rows as usize <= self.cfg.batch,
            "micro-batch {} rows {} beyond batch {}",
            frame.mb,
            frame.rows,
            self.cfg.batch
        );
        let (mut kc, mut vc) = self.kv.to_full()?;
        for r in 0..frame.rows as usize {
            if frame.active & (1 << r) == 0 {
                continue;
            }
            let pos = frame.pos.get(r).copied().unwrap_or(0) as usize;
            let row = frame
                .data
                .get_mut(r * dim..(r + 1) * dim)
                .ok_or_else(|| anyhow!("decode frame shorter than its header"))?;
            for (l, digest) in self.layers.iter().enumerate() {
                transform_row(row, pos, digest, l, base + r, &self.layout, self.cfg.batch, &mut kc, &mut vc);
            }
        }
        self.kv.swap_from_full(&kc, &vc)?;
        Ok(frame)
    }
}

/// The attention-lite per-layer transform: write k/v at `pos` from the
/// hidden row, read the running mean of v over positions 0..=pos, mix
/// with the layer digest, soft-clamp. Every operation is f32 in a fixed
/// order — the partition only changes WHO runs a layer, never the
/// arithmetic, which is the bit-identity invariant the property tests
/// pin down.
#[allow(clippy::too_many_arguments)]
fn transform_row(
    row: &mut [f32],
    pos: usize,
    digest: &LayerDigest,
    layer: usize,
    slot: usize,
    layout: &KvLayout,
    batch: usize,
    kc: &mut [f32],
    vc: &mut [f32],
) {
    let (seq, dh) = (layout.seq, layout.d_head);
    let lse = layout.layer_slot_elems();
    let base = (layer * batch + slot) * lse;
    for (j, h) in row.iter().enumerate() {
        let c = digest.coef.get(j).copied().unwrap_or(0.0);
        let off = base + (j / dh) * seq * dh + pos * dh + (j % dh);
        if let (Some(k), Some(v)) = (kc.get_mut(off), vc.get_mut(off)) {
            *k = h * 0.5 + c;
            *v = h - 0.25 * c;
        }
    }
    for (j, h) in row.iter_mut().enumerate() {
        let c = digest.coef.get(j).copied().unwrap_or(0.0);
        let col = base + (j / dh) * seq * dh + (j % dh);
        let mut sum = 0.0f32;
        for t in 0..=pos {
            sum += vc.get(col + t * dh).copied().unwrap_or(0.0);
        }
        let mean = sum / (pos + 1) as f32;
        let mixed = *h + 0.5 * mean + 0.125 * c;
        *h = squash(mixed);
    }
}

/// Soft clamp keeping hidden magnitudes bounded across deep stacks
/// (deterministic; monotone; sign-preserving).
fn squash(x: f32) -> f32 {
    x / (1.0 + 0.0625 * x.abs())
}

/// Greedy "sampling": hash the final hidden row's f32 bit patterns into
/// the vocabulary. Bit-stable by construction.
fn sample_token(row: &[f32], vocab: usize) -> i32 {
    let h = crate::util::fnv1a(row.iter().flat_map(|x| x.to_le_bytes()));
    (h % vocab.max(1) as u64) as i32
}

/// Deterministic token embedding (FNV-mixed), the coordinator-side
/// stand-in for an embedding table.
fn embed_token(tok: i32, out: &mut [f32]) {
    for (j, o) in out.iter_mut().enumerate() {
        let h = crate::util::fnv1a(
            tok.to_le_bytes().into_iter().chain((j as u32).to_le_bytes()),
        );
        *o = ((h >> 16) % 4096) as f32 / 2048.0 - 1.0;
    }
}

/// Run a whole arrival trace through a fresh pipeline and report — the
/// churn-harness analogue (`run_churn`) for pipeline execution.
pub fn run_pipeline(
    cfg: &PipelineConfig,
    source: &PipelineSource,
    arrivals: Vec<(u64, Request)>,
) -> Result<PipelineReport> {
    let mut pc = PipelineCoordinator::new(cfg.clone(), source)?;
    pc.run_arrivals(arrivals)?;
    pc.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::churn::{churn_arrivals, ChurnConfig};

    fn small_cfg(shards: usize, mb: usize) -> PipelineConfig {
        PipelineConfig {
            shards,
            micro_batches: mb,
            batch: 3,
            seq: 24,
            heads: 2,
            d_head: 3,
            vocab: 61,
            layers: 4,
            seed: 7,
            ..Default::default()
        }
    }

    fn arrivals(n: usize) -> Vec<(u64, Request)> {
        churn_arrivals(&ChurnConfig {
            n_requests: n,
            prompt_len: (4, 6),
            long_frac: 0.3,
            long_prompt_len: (10, 12),
            max_new: (4, 6),
            mean_gap_steps: 1.0,
            seed: 0xABCD,
            ..Default::default()
        })
    }

    #[test]
    fn single_shard_completes_everything() {
        let rep = run_pipeline(&small_cfg(1, 1), &PipelineSource::Synthetic, arrivals(8)).unwrap();
        assert_eq!(rep.completions.len(), 8, "{}", rep.metrics.summary());
        assert_eq!(rep.blocks_leaked, 0);
        assert_eq!(rep.metrics.internal_errors, 0);
        assert!(rep.total_frames() > 0);
        // N=1, F=1 degenerates to the engine's step cost: bubble is 0
        assert_eq!(rep.metrics.pipeline_bubble_ms, 0.0);
        assert!((rep.metrics.shard_lanes[0].busy_ms - rep.steps as f64).abs() < 1e-6);
    }

    #[test]
    fn shard_counts_agree_bitwise() {
        let base = run_pipeline(&small_cfg(1, 1), &PipelineSource::Synthetic, arrivals(8)).unwrap();
        for (shards, mb) in [(2usize, 1usize), (2, 3), (4, 2)] {
            let rep =
                run_pipeline(&small_cfg(shards, mb), &PipelineSource::Synthetic, arrivals(8))
                    .unwrap();
            assert_eq!(rep.completions.len(), base.completions.len());
            for (a, b) in base.completions.iter().zip(&rep.completions) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.tokens, b.tokens, "tokens diverged at {shards} shards mb {mb}");
            }
            assert_eq!(rep.admission_steps, base.admission_steps);
            assert_eq!(rep.completion_steps, base.completion_steps);
        }
    }

    #[test]
    fn micro_batch_partition_math() {
        // B=3, K=2 → mb_size 2 → F=2; K=16 → mb_size 1 → F=3
        let pc = PipelineCoordinator::new(small_cfg(1, 2), &PipelineSource::Synthetic).unwrap();
        assert_eq!(pc.micro_batches(), 2);
        let _ = pc.finish().unwrap();
        let pc = PipelineCoordinator::new(small_cfg(1, 16), &PipelineSource::Synthetic).unwrap();
        assert_eq!(pc.micro_batches(), 3);
        let _ = pc.finish().unwrap();
    }

    #[test]
    fn corrupt_frame_counts_internal_error() {
        let mut pc =
            PipelineCoordinator::new(small_cfg(2, 1), &PipelineSource::Synthetic).unwrap();
        pc.submit(Request { id: 1, prompt: vec![3, 1, 4], max_new: 4, arrival_ms: 0 });
        // a corrupt frame reaches shard 0 before the real prefill: the
        // worker errors out, the coordinator's ring traversal fails
        pc.inject_raw_downstream(vec![0xde, 0xad, 0xbe, 0xef, 9, 9]).unwrap();
        assert!(pc.tick().is_err());
        assert!(pc.metrics.internal_errors >= 1);
        let rep = pc.finish().unwrap();
        assert!(rep.metrics.internal_errors >= 1);
    }

    #[test]
    fn shard_router_submission_and_drain() {
        let router =
            crate::serve::router::ShardRouter::spawn(small_cfg(2, 2), PipelineSource::Synthetic);
        for (i, (_, mut r)) in arrivals(5).into_iter().enumerate() {
            r.id = i as u64;
            router.submit(r);
        }
        let rep = router.finish().unwrap();
        assert_eq!(rep.completions.len(), 5, "{}", rep.metrics.summary());
        assert_eq!(rep.blocks_leaked, 0);
    }

    #[test]
    fn socket_ring_matches_local_ring() {
        let local = run_pipeline(&small_cfg(2, 2), &PipelineSource::Synthetic, arrivals(6)).unwrap();
        let cfg = PipelineConfig { socket: true, ..small_cfg(2, 2) };
        let sock = run_pipeline(&cfg, &PipelineSource::Synthetic, arrivals(6)).unwrap();
        assert_eq!(local.completions.len(), sock.completions.len());
        for (a, b) in local.completions.iter().zip(&sock.completions) {
            assert_eq!((a.id, &a.tokens), (b.id, &b.tokens));
        }
        assert_eq!(local.total_wire_bytes(), sock.total_wire_bytes());
    }

    #[test]
    fn tcp_ring_matches_local_ring() {
        let local = run_pipeline(&small_cfg(2, 2), &PipelineSource::Synthetic, arrivals(6)).unwrap();
        let cfg = PipelineConfig { tcp: true, ..small_cfg(2, 2) };
        let tcp = run_pipeline(&cfg, &PipelineSource::Synthetic, arrivals(6)).unwrap();
        assert_eq!(local.completions.len(), tcp.completions.len());
        for (a, b) in local.completions.iter().zip(&tcp.completions) {
            assert_eq!((a.id, &a.tokens), (b.id, &b.tokens));
        }
        assert_eq!(local.total_wire_bytes(), tcp.total_wire_bytes());
    }

    #[test]
    fn token_events_stream_matches_completions() {
        let mut pc =
            PipelineCoordinator::new(small_cfg(2, 1), &PipelineSource::Synthetic).unwrap();
        pc.set_token_recording(true);
        pc.submit(Request { id: 5, prompt: vec![1, 2, 3], max_new: 4, arrival_ms: 0 });
        pc.submit(Request { id: 6, prompt: vec![4, 5], max_new: 3, arrival_ms: 0 });
        let mut streamed: std::collections::BTreeMap<u64, Vec<i32>> = Default::default();
        let mut done = Vec::new();
        while done.len() < 2 {
            let cs = pc.tick().unwrap();
            for ev in pc.take_token_events() {
                let toks = streamed.entry(ev.id).or_default();
                assert_eq!(ev.index, toks.len(), "token indices must be gapless");
                toks.push(ev.token);
            }
            done.extend(cs);
        }
        assert!(pc.take_token_events().is_empty());
        for c in &done {
            assert_eq!(streamed.get(&c.id), Some(&c.tokens), "stream != completion for {}", c.id);
        }
        // recording is opt-in: a fresh coordinator records nothing
        let mut quiet =
            PipelineCoordinator::new(small_cfg(1, 1), &PipelineSource::Synthetic).unwrap();
        quiet.submit(Request { id: 9, prompt: vec![1], max_new: 2, arrival_ms: 0 });
        while quiet.tick().unwrap().is_empty() {}
        assert!(quiet.take_token_events().is_empty());
        let _ = pc.finish().unwrap();
        let _ = quiet.finish().unwrap();
    }

    #[test]
    fn per_shard_kv_shrinks_with_shard_count() {
        let one = run_pipeline(&small_cfg(1, 1), &PipelineSource::Synthetic, arrivals(3)).unwrap();
        let four = run_pipeline(&small_cfg(4, 1), &PipelineSource::Synthetic, arrivals(3)).unwrap();
        let kv1 = one.worker_reports[0].kv_bytes;
        let kv4: u64 = four.worker_reports.iter().map(|w| w.kv_bytes).sum();
        assert_eq!(kv1, kv4, "total KV bytes conserved across the split");
        let max4 = four.worker_reports.iter().map(|w| w.kv_bytes).max().unwrap();
        assert_eq!(max4, kv1 / 4, "per-shard KV is 1/N of the model's");
    }
}
