//! Router: the threaded serving front ends. Clients submit requests via
//! a channel; a coordinator thread owns the engine and runs the
//! admission + generation loop; completions stream back on a channel.
//! Two roles share the shape:
//!
//! * [`Router`] — single-process: the coordinator owns the XLA engine
//!   (PJRT handles are not Send) and runs the batcher + generation
//!   loop.
//! * [`ShardRouter`] — pipeline-parallel: the coordinator owns a
//!   [`PipelineCoordinator`] and the N shard workers behind it,
//!   streaming activation frames around the transport ring.

use super::backend::Backend;
use super::batcher::{Batcher, BatcherConfig};
use super::engine::{Completion, GenerationEngine};
use super::metrics::ServeMetrics;
use super::pipeline::{PipelineConfig, PipelineCoordinator, PipelineReport, PipelineSource};
use super::trace::{QueuedRequest, Request};
use crate::config::ModelConfig;
use crate::model::Weights;
use crate::quant::QuantizedModel;
use crate::runtime::Engine;
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::mpsc;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct RouterConfig {
    pub backend: Backend,
    pub batch: usize,
    pub batcher: BatcherConfig,
    /// coordinator exits after this long with no work
    pub idle_timeout: Duration,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            backend: Backend::Dense,
            batch: 4,
            batcher: BatcherConfig::default(),
            idle_timeout: Duration::from_millis(200),
        }
    }
}

pub enum RouterMsg {
    Submit(Request),
    Shutdown,
}

pub struct Router {
    pub tx: mpsc::Sender<RouterMsg>,
    pub completions: mpsc::Receiver<Completion>,
    handle: std::thread::JoinHandle<Result<ServeMetrics>>,
}

impl Router {
    /// Spawn the coordinator thread. `artifacts` because the XLA client
    /// must be constructed inside the thread.
    pub fn spawn(
        cfg: ModelConfig,
        rcfg: RouterConfig,
        weights: Weights,
        qmodel: Option<QuantizedModel>,
    ) -> Router {
        let (tx, rx) = mpsc::channel::<RouterMsg>();
        let (ctx, crx) = mpsc::channel::<Completion>();
        let handle = crate::util::pool::spawn_worker("router", move || -> Result<ServeMetrics> {
            let engine = Engine::new()?;
            let mut ge = GenerationEngine::new(
                &engine,
                cfg,
                rcfg.backend.clone(),
                rcfg.batch,
                &weights,
                qmodel.as_ref(),
            )?;
            let mut batcher = Batcher::new(rcfg.batcher.clone());
            // requests keep their batcher-push submission timestamps —
            // latency is measured from there, not from admission
            let mut queue: VecDeque<QueuedRequest> = VecDeque::new();
            let t0 = Instant::now();
            let mut last_work = Instant::now();
            let mut shutdown = false;
            loop {
                // drain the inbox without blocking
                loop {
                    match rx.try_recv() {
                        Ok(RouterMsg::Submit(r)) => {
                            // a fresh submission is work: it resets the
                            // safety-valve clock so requests arriving
                            // after an idle gap are never guillotined
                            last_work = Instant::now();
                            // stamp on the ENGINE's clock: queue-wait
                            // accounting needs one time origin end-to-end
                            batcher.push(r, ge.now_ms());
                        }
                        Ok(RouterMsg::Shutdown) => shutdown = true,
                        Err(mpsc::TryRecvError::Empty) => break,
                        Err(mpsc::TryRecvError::Disconnected) => {
                            shutdown = true;
                            break;
                        }
                    }
                }
                // batcher → admission queue (force when engine has room)
                let force = ge.idle_slots() > 0 && queue.is_empty();
                queue.extend(batcher.poll(ge.now_ms(), force || shutdown));
                ge.admit(&mut queue)?;
                if ge.active_slots() > 0 {
                    for c in ge.step()? {
                        let _ = ctx.send(c);
                    }
                    last_work = Instant::now();
                } else if shutdown && batcher.pending() == 0 && queue.is_empty() {
                    break;
                } else if shutdown && last_work.elapsed() > rcfg.idle_timeout {
                    // shutdown with work that never became admissible:
                    // count it as dropped instead of losing it silently
                    let stuck = batcher.pending() + queue.len();
                    if stuck > 0 {
                        log::error!("shutdown dropping {stuck} unserved request(s)");
                        ge.metrics.dropped += stuck as u64;
                    }
                    break;
                } else if last_work.elapsed() > rcfg.idle_timeout.mul_f32(20.0)
                    && (batcher.pending() > 0 || !queue.is_empty())
                {
                    // Safety valve: pending work but admission has made
                    // no progress for 20 idle periods (e.g. a request
                    // that can never fit). Give it one last chance,
                    // then drain it into the metrics — the coordinator
                    // must not spin forever, and requests must never be
                    // dropped invisibly. An EMPTY idle router keeps
                    // waiting: disconnected clients arrive via the
                    // shutdown path, and fresh submissions reset
                    // `last_work`.
                    // flush the batcher COMPLETELY (one poll caps at
                    // max_batch) so every stuck request is counted
                    loop {
                        let flushed = batcher.poll(ge.now_ms(), true);
                        if flushed.is_empty() {
                            break;
                        }
                        queue.extend(flushed);
                    }
                    if ge.admit(&mut queue)? > 0 {
                        last_work = Instant::now();
                        continue;
                    }
                    log::error!(
                        "router safety valve: dropping {} stuck request(s)",
                        queue.len()
                    );
                    ge.metrics.dropped += queue.len() as u64;
                    queue.clear();
                    break;
                } else {
                    std::thread::sleep(Duration::from_micros(200));
                }
            }
            ge.metrics.wall_secs = t0.elapsed().as_secs_f64();
            Ok(ge.metrics.clone())
        });
        Router { tx, completions: crx, handle }
    }

    pub fn submit(&self, req: Request) {
        let _ = self.tx.send(RouterMsg::Submit(req));
    }

    /// Signal shutdown and join, returning the run's metrics.
    pub fn finish(self) -> Result<ServeMetrics> {
        let _ = self.tx.send(RouterMsg::Shutdown);
        self.handle.join().map_err(|_| anyhow::anyhow!("router thread panicked"))?
    }
}

/// The router's shard-coordinator role: a long-lived thread owns the
/// [`PipelineCoordinator`] (and through it the whole transport ring and
/// its shard workers); clients get the same non-blocking submit handle
/// and completion stream as [`Router`], and `finish` drains the ring
/// and returns the full [`PipelineReport`].
pub struct ShardRouter {
    pub tx: mpsc::Sender<RouterMsg>,
    pub completions: mpsc::Receiver<Completion>,
    handle: std::thread::JoinHandle<Result<PipelineReport>>,
}

impl ShardRouter {
    pub fn spawn(cfg: PipelineConfig, source: PipelineSource) -> ShardRouter {
        let (tx, rx) = mpsc::channel::<RouterMsg>();
        let (ctx, crx) = mpsc::channel::<Completion>();
        let handle = crate::util::pool::spawn_worker(
            "shard-coordinator",
            move || -> Result<PipelineReport> {
                let mut pc = PipelineCoordinator::new(cfg, &source)?;
                let mut shutdown = false;
                loop {
                    loop {
                        match rx.try_recv() {
                            Ok(RouterMsg::Submit(r)) => pc.submit(r),
                            Ok(RouterMsg::Shutdown) => shutdown = true,
                            Err(mpsc::TryRecvError::Empty) => break,
                            Err(mpsc::TryRecvError::Disconnected) => {
                                shutdown = true;
                                break;
                            }
                        }
                    }
                    for c in pc.tick()? {
                        let _ = ctx.send(c);
                    }
                    if pc.active_slots() == 0 && pc.queue_len() == 0 {
                        if shutdown {
                            break;
                        }
                        // idle: block on the inbox instead of spinning
                        match rx.recv() {
                            Ok(RouterMsg::Submit(r)) => pc.submit(r),
                            Ok(RouterMsg::Shutdown) | Err(_) => break,
                        }
                    } else if pc.active_slots() == 0 && pc.queue_len() > 0 {
                        // nothing active yet nothing admissible: the
                        // queue head cannot fit even a fully-idle
                        // engine, so it never will — drain it into the
                        // drop counter instead of spinning forever
                        log::error!(
                            "shard router dropping {} unservable request(s)",
                            pc.queue_len()
                        );
                        pc.drop_queued();
                    }
                }
                pc.finish()
            },
        );
        ShardRouter { tx, completions: crx, handle }
    }

    pub fn submit(&self, req: Request) {
        let _ = self.tx.send(RouterMsg::Submit(req));
    }

    /// Signal shutdown, join the coordinator, return the run's report.
    pub fn finish(self) -> Result<PipelineReport> {
        let _ = self.tx.send(RouterMsg::Shutdown);
        self.handle.join().map_err(|_| anyhow::anyhow!("shard coordinator thread panicked"))?
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::trace::{generate_trace, TraceConfig};

    #[test]
    fn router_end_to_end() {
        if !crate::artifacts_dir().join("decode_dense_tiny_b1.hlo.txt").exists() {
            return;
        }
        let engine = Engine::new().unwrap();
        let cfg = ModelConfig::load_named(engine.artifacts(), "tiny").unwrap();
        let exe = engine.load("fwd_loss_tiny").unwrap();
        let w = Weights::from_manifest(cfg.clone(), &exe.manifest, Some(1)).unwrap();
        drop(engine);
        let corpus = crate::data::Corpus::new(cfg.vocab, cfg.seq, 1);
        let trace = generate_trace(
            &TraceConfig {
                n_requests: 4,
                prompt_len: (4, 8),
                max_new: (2, 4),
                ..Default::default()
            },
            &corpus,
        );
        let router = Router::spawn(
            cfg,
            RouterConfig { batch: 1, ..Default::default() },
            w,
            None,
        );
        for r in trace {
            router.submit(r);
        }
        let mut got = 0;
        // collect with timeout budget
        let deadline = Instant::now() + Duration::from_secs(60);
        while got < 4 && Instant::now() < deadline {
            if router.completions.recv_timeout(Duration::from_secs(30)).is_ok() {
                got += 1;
            } else {
                break;
            }
        }
        let metrics = router.finish().unwrap();
        assert_eq!(got, 4, "completions missing: {}", metrics.summary());
        assert_eq!(metrics.completions.len(), 4);
    }

    #[test]
    fn router_survives_idle_gap_longer_than_safety_valve() {
        // regression: the 20×idle_timeout safety valve used to kill the
        // coordinator outright, silently dropping anything submitted
        // afterwards. Submissions now reset the valve clock and stuck
        // work is drained into `metrics.dropped`, never lost silently.
        if !crate::artifacts_dir().join("decode_dense_tiny_b1.hlo.txt").exists() {
            return;
        }
        let engine = Engine::new().unwrap();
        let cfg = ModelConfig::load_named(engine.artifacts(), "tiny").unwrap();
        let exe = engine.load("fwd_loss_tiny").unwrap();
        let w = Weights::from_manifest(cfg.clone(), &exe.manifest, Some(1)).unwrap();
        drop(engine);
        let corpus = crate::data::Corpus::new(cfg.vocab, cfg.seq, 1);
        let mk = |n: usize, seed: u64| {
            generate_trace(
                &TraceConfig {
                    n_requests: n,
                    prompt_len: (4, 8),
                    max_new: (2, 3),
                    seed,
                    ..Default::default()
                },
                &corpus,
            )
        };
        let idle = Duration::from_millis(25);
        let router = Router::spawn(
            cfg,
            RouterConfig { batch: 1, idle_timeout: idle, ..Default::default() },
            w,
            None,
        );
        for r in mk(1, 3) {
            router.submit(r);
        }
        assert!(
            router.completions.recv_timeout(Duration::from_secs(60)).is_ok(),
            "first burst not served"
        );
        // idle PAST the 20×idle_timeout valve window, then submit again
        std::thread::sleep(idle.mul_f32(25.0));
        for mut r in mk(1, 9) {
            r.id += 100;
            router.submit(r);
        }
        assert!(
            router.completions.recv_timeout(Duration::from_secs(60)).is_ok(),
            "request submitted after the idle gap was dropped"
        );
        let metrics = router.finish().unwrap();
        assert_eq!(metrics.completions.len(), 2, "{}", metrics.summary());
        assert_eq!(metrics.dropped, 0, "{}", metrics.summary());
    }
}
