//! Slot-strided KV state — the O(new-slots) admission path.
//!
//! The engine used to hold its KV cache as two monolithic
//! `[layers, batch, heads, seq, d_head]` literals, and admission paid a
//! full download + splice + re-upload of BOTH for every admitted
//! request — `4 × layers·batch·heads·seq·d_head` floats crossing the
//! host↔literal boundary per admission, regardless of how many slots
//! were actually new. Under steady request churn that dwarfs decode
//! itself (PR 3 profiling; PERF.md §10).
//!
//! [`SlotKv`] restructures the state as ONE literal pair per slot
//! (vLLM-paged in spirit, matching the per-request accounting
//! `KvBlockManager` already keeps): the decode executable takes
//! `kcache_0..kcache_{B-1}, vcache_0..vcache_{B-1}` each shaped
//! `[layers, heads, seq, d_head]`, and prefill returns per-slot KV the
//! same way. Admission then *moves handles*: the new slots' prefill
//! outputs are installed directly, live slots' literals are never read,
//! copied, or re-uploaded.
//!
//! [`FullKv`] keeps the old full-splice path alive as `admit_reference`
//! — the equivalence oracle the churn property tests compare against
//! bit for bit (`rust/tests/prop_kv_admission.rs`), and the "before"
//! side of the admission benches. Both types count the bytes they move
//! across the host↔literal boundary in `admit_bytes`, which is what the
//! `kv_admit_*` benches in `micro_hotpaths` pin: strided bytes per
//! admit are constant in the live batch size; full-splice bytes scale
//! with it.

use crate::runtime::HostArg;
use anyhow::{ensure, Result};

/// The KV tensor geometry of one engine (everything but the batch).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvLayout {
    pub layers: usize,
    pub heads: usize,
    pub seq: usize,
    pub d_head: usize,
}

impl KvLayout {
    pub fn for_model(cfg: &crate::config::ModelConfig) -> Self {
        KvLayout {
            layers: cfg.n_layers,
            heads: cfg.n_heads,
            seq: cfg.seq,
            d_head: cfg.d_head(),
        }
    }

    /// Elements of one slot within one layer (`heads · seq · d_head`).
    pub fn layer_slot_elems(&self) -> usize {
        self.heads * self.seq * self.d_head
    }

    /// Elements of one slot's full KV tensor (`layers · heads · seq · d_head`).
    pub fn slot_elems(&self) -> usize {
        self.layers * self.layer_slot_elems()
    }

    /// Dims of one slot's literal: `[layers, heads, seq, d_head]`.
    pub fn slot_dims(&self) -> Vec<usize> {
        vec![self.layers, self.heads, self.seq, self.d_head]
    }

    /// Dims of the monolithic layout: `[layers, batch, heads, seq, d_head]`.
    pub fn full_dims(&self, batch: usize) -> Vec<usize> {
        vec![self.layers, batch, self.heads, self.seq, self.d_head]
    }

    pub fn full_elems(&self, batch: usize) -> usize {
        batch * self.slot_elems()
    }

    /// Bytes one slot's K+V pair occupies (f32).
    pub fn slot_kv_bytes(&self) -> u64 {
        2 * self.slot_elems() as u64 * 4
    }
}

/// Gather slot `b`'s strided region out of a full-layout host buffer.
fn gather_slot(layout: &KvLayout, batch: usize, b: usize, full: &[f32]) -> Vec<f32> {
    let lse = layout.layer_slot_elems();
    let mut out = Vec::with_capacity(layout.slot_elems());
    for l in 0..layout.layers {
        let off = (l * batch + b) * lse;
        out.extend_from_slice(&full[off..off + lse]);
    }
    out
}

/// Per-slot KV literals — the slot-strided engine state.
pub struct SlotKv {
    pub layout: KvLayout,
    k: Vec<xla::Literal>,
    v: Vec<xla::Literal>,
    /// bytes moved across the host↔literal boundary by admissions
    pub admit_bytes: u64,
}

impl SlotKv {
    /// Zero-initialized state for `batch` slots.
    pub fn new(layout: KvLayout, batch: usize) -> Result<Self> {
        ensure!(batch > 0, "SlotKv: batch must be >= 1");
        let dims = layout.slot_dims();
        let zero = || HostArg::F32(vec![0.0; layout.slot_elems()], dims.clone()).to_literal();
        let k = (0..batch).map(|_| zero()).collect::<Result<Vec<_>>>()?;
        let v = (0..batch).map(|_| zero()).collect::<Result<Vec<_>>>()?;
        Ok(SlotKv { layout, k, v, admit_bytes: 0 })
    }

    pub fn batch(&self) -> usize {
        self.k.len()
    }

    /// Borrowed executable arguments in ABI order:
    /// `kcache_0..kcache_{B-1}, vcache_0..vcache_{B-1}`.
    pub fn args(&self) -> Vec<&xla::Literal> {
        self.k.iter().chain(self.v.iter()).collect()
    }

    fn check_slot_dims(&self, what: &str, lit: &xla::Literal) -> Result<()> {
        let want: Vec<i64> = self.layout.slot_dims().iter().map(|&d| d as i64).collect();
        ensure!(
            lit.dims() == want.as_slice(),
            "{what}: literal dims {:?} do not match the slot layout {:?}",
            lit.dims(),
            want
        );
        Ok(())
    }

    /// Install one slot's freshly prefilled KV literals by HANDLE MOVE —
    /// zero host bytes touched, and no other slot's literal is read.
    /// This is the real engine's admission path.
    pub fn install_slot(&mut self, b: usize, k: xla::Literal, v: xla::Literal) -> Result<()> {
        ensure!(b < self.batch(), "install_slot: slot {b} out of range {}", self.batch());
        self.check_slot_dims("kcache", &k)?;
        self.check_slot_dims("vcache", &v)?;
        self.k[b] = k;
        self.v[b] = v;
        Ok(())
    }

    /// Swap in a decode step's per-slot output literals wholesale (the
    /// steady-state loop: no host round-trip, exactly like the old
    /// monolithic swap but per slot).
    pub fn replace_all(&mut self, k: Vec<xla::Literal>, v: Vec<xla::Literal>) -> Result<()> {
        ensure!(
            k.len() == self.batch() && v.len() == self.batch(),
            "replace_all: got {}/{} literals for batch {}",
            k.len(),
            v.len(),
            self.batch()
        );
        for lit in k.iter().chain(v.iter()) {
            self.check_slot_dims("kv", lit)?;
        }
        self.k = k;
        self.v = v;
        Ok(())
    }

    /// Admit from full-layout host buffers: gather ONLY the new slots'
    /// strided regions and upload one literal pair per new slot. Bytes
    /// moved: `2 · slot_elems · 4` per admitted slot — independent of
    /// the live batch size. (The XLA-free churn harness and benches use
    /// this; the real engine uses [`SlotKv::install_slot`], which moves
    /// zero bytes.)
    pub fn admit_from_full(&mut self, slots: &[usize], kc: &[f32], vc: &[f32]) -> Result<()> {
        let batch = self.batch();
        let want = self.layout.full_elems(batch);
        ensure!(
            kc.len() == want && vc.len() == want,
            "admit_from_full: buffers {}/{} vs full layout {want}",
            kc.len(),
            vc.len()
        );
        let dims = self.layout.slot_dims();
        for &b in slots {
            ensure!(b < batch, "admit_from_full: slot {b} out of range {batch}");
            let ks = gather_slot(&self.layout, batch, b, kc);
            let vs = gather_slot(&self.layout, batch, b, vc);
            self.k[b] = HostArg::F32(ks, dims.clone()).to_literal()?;
            self.v[b] = HostArg::F32(vs, dims.clone()).to_literal()?;
            self.admit_bytes += self.layout.slot_kv_bytes();
        }
        Ok(())
    }

    /// Replace EVERY slot from full-layout host buffers — the churn
    /// harness's simulated decode swap (not admission traffic, so not
    /// counted in `admit_bytes`).
    pub fn swap_from_full(&mut self, kc: &[f32], vc: &[f32]) -> Result<()> {
        let batch = self.batch();
        let want = self.layout.full_elems(batch);
        ensure!(
            kc.len() == want && vc.len() == want,
            "swap_from_full: buffers {}/{} vs full layout {want}",
            kc.len(),
            vc.len()
        );
        let dims = self.layout.slot_dims();
        for b in 0..batch {
            let ks = gather_slot(&self.layout, batch, b, kc);
            let vs = gather_slot(&self.layout, batch, b, vc);
            self.k[b] = HostArg::F32(ks, dims.clone()).to_literal()?;
            self.v[b] = HostArg::F32(vs, dims.clone()).to_literal()?;
        }
        Ok(())
    }

    /// Interleave the per-slot literals back into the monolithic
    /// `[layers, batch, heads, seq, d_head]` layout — the comparison
    /// point the equivalence property tests use.
    pub fn to_full(&self) -> Result<(Vec<f32>, Vec<f32>)> {
        Ok((self.scatter(&self.k)?, self.scatter(&self.v)?))
    }

    fn scatter(&self, lits: &[xla::Literal]) -> Result<Vec<f32>> {
        let batch = lits.len();
        let lse = self.layout.layer_slot_elems();
        let mut full = vec![0.0f32; self.layout.full_elems(batch)];
        for (b, lit) in lits.iter().enumerate() {
            let data: Vec<f32> =
                lit.to_vec().map_err(|e| anyhow::anyhow!("kv slot {b}: {e:?}"))?;
            for l in 0..self.layout.layers {
                let off = (l * batch + b) * lse;
                full[off..off + lse].copy_from_slice(&data[l * lse..(l + 1) * lse]);
            }
        }
        Ok(full)
    }
}

/// The pre-slot-strided KV state: two monolithic literals, kept as the
/// equivalence oracle and the "before" side of the admission benches.
pub struct FullKv {
    pub layout: KvLayout,
    batch: usize,
    k: xla::Literal,
    v: xla::Literal,
    /// bytes moved across the host↔literal boundary by admissions
    pub admit_bytes: u64,
}

impl FullKv {
    pub fn new(layout: KvLayout, batch: usize) -> Result<Self> {
        ensure!(batch > 0, "FullKv: batch must be >= 1");
        let dims = layout.full_dims(batch);
        let n = layout.full_elems(batch);
        let zero = || HostArg::F32(vec![0.0; n], dims.clone()).to_literal();
        Ok(FullKv { layout, batch, k: zero()?, v: zero()?, admit_bytes: 0 })
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// The reference admission path (what `GenerationEngine::admit` did
    /// before this refactor): download BOTH full literals, splice the
    /// new slots' strided regions, re-upload everything. Bytes moved:
    /// `4 · full_elems · 4` per call — proportional to the WHOLE cache
    /// no matter how few slots were admitted.
    pub fn admit_reference(&mut self, slots: &[usize], kc: &[f32], vc: &[f32]) -> Result<()> {
        let want = self.layout.full_elems(self.batch);
        ensure!(
            kc.len() == want && vc.len() == want,
            "admit_reference: buffers {}/{} vs full layout {want}",
            kc.len(),
            vc.len()
        );
        let mut k: Vec<f32> = self.k.to_vec().map_err(|e| anyhow::anyhow!("kv_k: {e:?}"))?;
        let mut v: Vec<f32> = self.v.to_vec().map_err(|e| anyhow::anyhow!("kv_v: {e:?}"))?;
        let lse = self.layout.layer_slot_elems();
        for &b in slots {
            ensure!(b < self.batch, "admit_reference: slot {b} out of range {}", self.batch);
            for l in 0..self.layout.layers {
                let off = (l * self.batch + b) * lse;
                k[off..off + lse].copy_from_slice(&kc[off..off + lse]);
                v[off..off + lse].copy_from_slice(&vc[off..off + lse]);
            }
        }
        let dims = self.layout.full_dims(self.batch);
        self.k = HostArg::F32(k, dims.clone()).to_literal()?;
        self.v = HostArg::F32(v, dims).to_literal()?;
        self.admit_bytes += 4 * want as u64 * 4;
        Ok(())
    }

    /// Replace the whole state from full-layout host buffers (simulated
    /// decode swap; not admission traffic).
    pub fn swap_host(&mut self, kc: &[f32], vc: &[f32]) -> Result<()> {
        let want = self.layout.full_elems(self.batch);
        ensure!(
            kc.len() == want && vc.len() == want,
            "swap_host: buffers {}/{} vs full layout {want}",
            kc.len(),
            vc.len()
        );
        let dims = self.layout.full_dims(self.batch);
        self.k = HostArg::F32(kc.to_vec(), dims.clone()).to_literal()?;
        self.v = HostArg::F32(vc.to_vec(), dims).to_literal()?;
        Ok(())
    }

    pub fn to_full(&self) -> Result<(Vec<f32>, Vec<f32>)> {
        let k = self.k.to_vec().map_err(|e| anyhow::anyhow!("kv_k: {e:?}"))?;
        let v = self.v.to_vec().map_err(|e| anyhow::anyhow!("kv_v: {e:?}"))?;
        Ok((k, v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn layout() -> KvLayout {
        KvLayout { layers: 3, heads: 2, seq: 8, d_head: 4 }
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn layout_math() {
        let l = layout();
        assert_eq!(l.layer_slot_elems(), 2 * 8 * 4);
        assert_eq!(l.slot_elems(), 3 * 2 * 8 * 4);
        assert_eq!(l.slot_dims(), vec![3, 2, 8, 4]);
        assert_eq!(l.full_dims(5), vec![3, 5, 2, 8, 4]);
        assert_eq!(l.full_elems(5), 5 * l.slot_elems());
        assert_eq!(l.slot_kv_bytes(), 2 * l.slot_elems() as u64 * 4);
    }

    #[test]
    fn strided_matches_full_splice() {
        // interleaved admissions into different slots must leave both
        // layouts bit-identical under to_full()
        let l = layout();
        let batch = 4;
        let mut rng = Rng::new(7);
        let mut s = SlotKv::new(l, batch).unwrap();
        let mut f = FullKv::new(l, batch).unwrap();
        for (round, slots) in [vec![0usize, 2], vec![1], vec![2, 3], vec![0]]
            .into_iter()
            .enumerate()
        {
            let kc = rng.normal_vec(l.full_elems(batch));
            let vc = rng.normal_vec(l.full_elems(batch));
            s.admit_from_full(&slots, &kc, &vc).unwrap();
            f.admit_reference(&slots, &kc, &vc).unwrap();
            let (sk, sv) = s.to_full().unwrap();
            let (fk, fv) = f.to_full().unwrap();
            assert_eq!(bits(&sk), bits(&fk), "round {round}: k diverged");
            assert_eq!(bits(&sv), bits(&fv), "round {round}: v diverged");
        }
        // decode swap keeps them aligned too
        let kc = rng.normal_vec(l.full_elems(batch));
        let vc = rng.normal_vec(l.full_elems(batch));
        s.swap_from_full(&kc, &vc).unwrap();
        f.swap_host(&kc, &vc).unwrap();
        let (sk, _) = s.to_full().unwrap();
        let (fk, _) = f.to_full().unwrap();
        assert_eq!(bits(&sk), bits(&fk));
    }

    #[test]
    fn install_slot_roundtrip() {
        let l = layout();
        let mut s = SlotKv::new(l, 2).unwrap();
        let mut rng = Rng::new(3);
        let data = rng.normal_vec(l.slot_elems());
        let lit = |d: &[f32]| HostArg::F32(d.to_vec(), l.slot_dims()).to_literal().unwrap();
        s.install_slot(1, lit(&data), lit(&data)).unwrap();
        assert_eq!(s.admit_bytes, 0, "handle move must not count as moved bytes");
        let (k, _) = s.to_full().unwrap();
        // slot 1's strided region carries the installed data, slot 0 stays zero
        let lse = l.layer_slot_elems();
        for layer in 0..l.layers {
            let off0 = (layer * 2) * lse;
            let off1 = (layer * 2 + 1) * lse;
            assert!(k[off0..off0 + lse].iter().all(|&x| x == 0.0));
            assert_eq!(bits(&k[off1..off1 + lse]), bits(&data[layer * lse..(layer + 1) * lse]));
        }
    }

    #[test]
    fn admit_bytes_accounting() {
        // strided: per-admit bytes are constant in the batch size;
        // full-splice: per-admit bytes scale with it
        let l = layout();
        for batch in [2usize, 8] {
            let mut rng = Rng::new(11);
            let kc = rng.normal_vec(l.full_elems(batch));
            let vc = rng.normal_vec(l.full_elems(batch));
            let mut s = SlotKv::new(l, batch).unwrap();
            s.admit_from_full(&[0], &kc, &vc).unwrap();
            assert_eq!(s.admit_bytes, l.slot_kv_bytes(), "batch {batch}");
            let mut f = FullKv::new(l, batch).unwrap();
            f.admit_reference(&[0], &kc, &vc).unwrap();
            assert_eq!(f.admit_bytes, 4 * l.full_elems(batch) as u64 * 4, "batch {batch}");
        }
    }

    #[test]
    fn shape_errors_rejected() {
        let l = layout();
        let mut s = SlotKv::new(l, 2).unwrap();
        let bad = HostArg::F32(vec![0.0; 4], vec![4]).to_literal().unwrap();
        let good = HostArg::F32(vec![0.0; l.slot_elems()], l.slot_dims()).to_literal().unwrap();
        assert!(s.install_slot(0, bad, good.clone()).is_err());
        assert!(s.install_slot(5, good.clone(), good.clone()).is_err());
        assert!(s.replace_all(vec![good.clone()], vec![good.clone()]).is_err());
        assert!(s.admit_from_full(&[0], &[0.0; 3], &[0.0; 3]).is_err());
        let mut f = FullKv::new(l, 2).unwrap();
        assert!(f.admit_reference(&[0], &[0.0; 3], &[0.0; 3]).is_err());
        let full = vec![0.0; l.full_elems(2)];
        assert!(f.admit_reference(&[7], &full, &full).is_err());
    }
}
