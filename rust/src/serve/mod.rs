//! Serving coordinator — the L3 system contribution (paper §4.3,
//! Table 1): a request router + continuous batcher + KV-cache manager
//! in front of the AOT generation executables, with pluggable weight
//! backends (FP16 dense / uniform-MARLIN / NF-LUT / FLUTE-HIGGS).
//!
//! Architecture (vLLM-router-like, std::thread based):
//!
//! ```text
//!   clients ──mpsc──▶ Router ──▶ Batcher (deadline+size) ──▶ Engine
//!                                                     │  prefill/decode
//!                       metrics ◀── completions ◀─────┘  (PJRT execs)
//! ```
//!
//! Fixed-shape executables force a static max batch; the engine does
//! continuous batching by slot reuse: finished slots are refilled from
//! the queue via a merged prefill without disturbing live slots' KV.
//!
//! Pipeline-parallel execution ([`pipeline`], PERF.md §12) splits the
//! layer stack across N shard workers behind the same router shape:
//!
//! ```text
//!   clients ──mpsc──▶ ShardRouter ──▶ PipelineCoordinator
//!                        │   frames: coord ─▶ shard 0 ─▶ … ─▶ shard N−1 ─▶ coord
//!                        └◀─ completions    (ShardTransport ring, K micro-batches)
//! ```

pub mod backend;
pub mod batcher;
pub mod churn;
pub mod engine;
pub mod kvcache;
pub mod kvstate;
pub mod metrics;
pub mod pipeline;
pub mod planes;
pub mod router;
pub mod trace;
pub mod transport;

pub use backend::{Backend, QuantSource};
pub use churn::{run_churn, ChurnConfig, ChurnReport, KvMode};
pub use engine::GenerationEngine;
pub use kvstate::{FullKv, KvLayout, SlotKv};
pub use metrics::{CompletionStat, ServeMetrics, ShardLane};
pub use pipeline::{
    run_pipeline, PipelineConfig, PipelineCoordinator, PipelineReport, PipelineSource,
};
pub use router::{Router, RouterConfig, ShardRouter};
pub use trace::{Clock, QueuedRequest, Request, TraceConfig};
pub use transport::{ActivationFrame, LocalPipe, ShardTransport, SocketTransport};
