//! Serving coordinator — the L3 system contribution (paper §4.3,
//! Table 1): a request router + continuous batcher + KV-cache manager
//! in front of the AOT generation executables, with pluggable weight
//! backends (FP16 dense / uniform-MARLIN / NF-LUT / FLUTE-HIGGS).
//!
//! Architecture (vLLM-router-like, std::thread based):
//!
//! ```text
//!   clients ──mpsc──▶ Router ──▶ Batcher (deadline+size) ──▶ Engine
//!                                                     │  prefill/decode
//!                       metrics ◀── completions ◀─────┘  (PJRT execs)
//! ```
//!
//! Fixed-shape executables force a static max batch; the engine does
//! continuous batching by slot reuse: finished slots are refilled from
//! the queue via a merged prefill without disturbing live slots' KV.
//!
//! Pipeline-parallel execution ([`pipeline`], PERF.md §12) splits the
//! layer stack across N shard workers behind the same router shape:
//!
//! ```text
//!   clients ──mpsc──▶ ShardRouter ──▶ PipelineCoordinator
//!                        │   frames: coord ─▶ shard 0 ─▶ … ─▶ shard N−1 ─▶ coord
//!                        └◀─ completions    (ShardTransport ring, K micro-batches)
//! ```
//!
//! The network front-end ([`daemon`], PERF.md §13) puts a TCP accept
//! loop speaking the [`wire`] request protocol in front of the same
//! coordinator, with streamed tokens, per-request lifecycle spans
//! ([`spans`]), bounded admission, deadlines, and graceful drain:
//!
//! ```text
//!   TCP clients ──▶ higgs serve-daemon ──▶ DaemonCore ──▶ PipelineCoordinator
//!         ◀─ Token…/Done streams, Busy, typed Errors ◀──┘  (spans → JSONL)
//! ```

pub mod backend;
pub mod batcher;
pub mod churn;
pub mod daemon;
pub mod engine;
pub mod kvcache;
pub mod kvstate;
pub mod metrics;
pub mod pipeline;
pub mod planes;
pub mod router;
pub mod spans;
pub mod trace;
pub mod transport;
pub mod wire;

pub use backend::{Backend, QuantSource};
pub use churn::{run_churn, ChurnConfig, ChurnReport, KvMode};
pub use daemon::{
    drain_daemon, request_many, run_core, ClientOutcome, ClientRequest, CoreMsg, Daemon,
    DaemonConfig, DaemonReport,
};
pub use engine::GenerationEngine;
pub use kvstate::{FullKv, KvLayout, SlotKv};
pub use metrics::{CompletionStat, PhaseStats, ServeMetrics, ShardLane};
pub use pipeline::{
    run_pipeline, PipelineConfig, PipelineCoordinator, PipelineReport, PipelineSource, TokenEvent,
};
pub use router::{Router, RouterConfig, ShardRouter};
pub use spans::{phase_stats, RequestSpan, SpanOutcome, SpanRing};
pub use trace::{Clock, QueuedRequest, Request, TraceConfig};
pub use transport::{
    ActivationFrame, LocalPipe, ShardTransport, SocketTransport, TcpTransport,
};
pub use wire::{ErrorCode, FinishReason, WireMsg};
