//! Serving coordinator — the L3 system contribution (paper §4.3,
//! Table 1): a request router + continuous batcher + KV-cache manager
//! in front of the AOT generation executables, with pluggable weight
//! backends (FP16 dense / uniform-MARLIN / NF-LUT / FLUTE-HIGGS).
//!
//! Architecture (vLLM-router-like, std::thread based):
//!
//! ```text
//!   clients ──mpsc──▶ Router ──▶ Batcher (deadline+size) ──▶ Engine
//!                                                     │  prefill/decode
//!                       metrics ◀── completions ◀─────┘  (PJRT execs)
//! ```
//!
//! Fixed-shape executables force a static max batch; the engine does
//! continuous batching by slot reuse: finished slots are refilled from
//! the queue via a merged prefill without disturbing live slots' KV.

pub mod backend;
pub mod batcher;
pub mod churn;
pub mod engine;
pub mod kvcache;
pub mod kvstate;
pub mod metrics;
pub mod planes;
pub mod router;
pub mod trace;

pub use backend::{Backend, QuantSource};
pub use churn::{run_churn, ChurnConfig, ChurnReport, KvMode};
pub use engine::GenerationEngine;
pub use kvstate::{FullKv, KvLayout, SlotKv};
pub use metrics::{CompletionStat, ServeMetrics};
pub use planes::PlaneStore;
pub use router::{Router, RouterConfig};
pub use trace::{Clock, QueuedRequest, Request, TraceConfig};
