//! The network serving daemon (PERF.md §13): a long-lived TCP
//! front-end (`higgs serve-daemon`) speaking the [`wire`](super::wire)
//! request protocol in front of the pipeline coordinator — the first
//! process a CLIENT can actually send a request to.
//!
//! ```text
//!   clients ──TCP──▶ accept loop ──▶ per-conn workers ──mpsc──▶ DaemonCore
//!     ◀─ Token / Done / Error / Busy streams ◀── reply channels ──┘  │ tick
//!                                                        PipelineCoordinator
//! ```
//!
//! Lifecycle contract:
//!   * **streaming**: every generated token is pushed to the client as
//!     it is produced (the coordinator's opt-in [`TokenEvent`] seam),
//!     terminal `Done` carries the finish reason + latency split;
//!   * **backpressure**: admission is bounded (`max_queue`); an
//!     overflowing or draining daemon answers a typed `Busy`, never
//!     queues unboundedly;
//!   * **deadlines**: a request whose deadline expires while it is
//!     still QUEUED gets a typed timeout `Error`. Deadlines are
//!     enforced on the daemon's [`Clock`](super::trace::Clock) —
//!     virtual-clock tests exercise them sleep-free. Once admitted, a
//!     request runs to completion (a mid-decode cancel would desync
//!     the bit-identity contract);
//!   * **graceful drain**: a `Drain` message (or [`Daemon::finish`])
//!     stops admission, finishes every in-flight decode, streams the
//!     tails, acks the drain, and exits with a final report;
//!   * **corruption**: a corrupt or truncated client frame closes that
//!     connection and counts in `internal_errors` — the daemon keeps
//!     serving everyone else.
//!
//! Every request carries a [`RequestSpan`]; finished spans land in the
//! ring ([`SpanRing`], `HIGGS_TRACE_RING`) and fold into
//! `ServeMetrics::phases` / the optional `--trace-out` JSONL dump.
//!
//! This module is under the `wall-clock` audit rule: all timing flows
//! through the coordinator's `Clock` — no `Instant`, no sleeps.

use super::engine::Completion;
use super::metrics::ServeMetrics;
use super::pipeline::{PipelineConfig, PipelineCoordinator, PipelineSource, TokenEvent};
use super::spans::{phase_stats, RequestSpan, SpanOutcome, SpanRing};
use super::trace::Request;
use super::wire::{read_msg, write_msg, ErrorCode, FinishReason, WireMsg};
use anyhow::{anyhow, bail, ensure, Result};
use std::collections::{BTreeMap, VecDeque};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

#[derive(Clone, Debug)]
pub struct DaemonConfig {
    /// bind address; port 0 picks an ephemeral port (tests)
    pub listen: String,
    /// bounded admission: pending requests beyond this bounce as `Busy`
    pub max_queue: usize,
    /// applied to submits that carry `deadline_ms == 0`; 0 = no deadline
    pub default_deadline_ms: u32,
    /// span ring capacity (see [`SpanRing::default_capacity`])
    pub trace_ring: usize,
    /// dump the span ring as JSONL here at shutdown
    pub trace_out: Option<PathBuf>,
    pub pipeline: PipelineConfig,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        DaemonConfig {
            listen: "127.0.0.1:0".to_string(),
            max_queue: 64,
            default_deadline_ms: 0,
            trace_ring: 1024,
            trace_out: None,
            pipeline: PipelineConfig { shards: 1, ..Default::default() },
        }
    }
}

/// What the core loop receives from connection workers (and from
/// direct-drive tests — the deterministic seam for drain/deadline
/// semantics, no TCP races involved).
pub enum CoreMsg {
    Submit {
        /// connection id (0 for direct drives)
        client: u64,
        /// the CLIENT's request id, echoed on every reply
        id: u64,
        prompt: Vec<i32>,
        max_new: u32,
        deadline_ms: u32,
        reply: mpsc::Sender<WireMsg>,
    },
    /// stop admitting, finish in-flight work, ack with `WireMsg::Drain`
    Drain { reply: mpsc::Sender<WireMsg> },
    /// a connection saw a corrupt frame (counted in `internal_errors`)
    WireError,
}

/// The daemon's final accounting.
pub struct DaemonReport {
    pub metrics: ServeMetrics,
    /// completions sorted by internal id — the bit-identity surface
    pub completions: Vec<Completion>,
    pub steps: u64,
    pub shards: usize,
    pub busy_rejections: u64,
    pub timeouts: u64,
    pub wire_errors: u64,
    pub spans: SpanRing,
}

struct Pending {
    internal: u64,
    client_req: u64,
    prompt: Vec<i32>,
    max_new: u32,
    deadline_ms: u32,
    reply: mpsc::Sender<WireMsg>,
    span: RequestSpan,
}

struct Live {
    client_req: u64,
    max_new: u32,
    reply: mpsc::Sender<WireMsg>,
    span: RequestSpan,
}

struct DaemonCore {
    cfg: DaemonConfig,
    pc: PipelineCoordinator,
    pending: VecDeque<Pending>,
    live: BTreeMap<u64, Live>,
    ring: SpanRing,
    drain_replies: Vec<mpsc::Sender<WireMsg>>,
    draining: bool,
    next_internal: u64,
    busy_rejections: u64,
    rejected: u64,
    timeouts: u64,
    wire_errors: u64,
}

/// Run the daemon core to completion: consume [`CoreMsg`]s from `rx`,
/// drive the pipeline, stream replies, and return the final report
/// once drained (or once every sender is gone and the queue is dry).
pub fn run_core(
    cfg: DaemonConfig,
    source: &PipelineSource,
    rx: mpsc::Receiver<CoreMsg>,
) -> Result<DaemonReport> {
    let mut pc = PipelineCoordinator::new(cfg.pipeline.clone(), source)?;
    pc.set_token_recording(true);
    let ring = SpanRing::new(cfg.trace_ring);
    let mut core = DaemonCore {
        cfg,
        pc,
        pending: VecDeque::new(),
        live: BTreeMap::new(),
        ring,
        drain_replies: Vec::new(),
        draining: false,
        next_internal: 0,
        busy_rejections: 0,
        rejected: 0,
        timeouts: 0,
        wire_errors: 0,
    };
    core.run(rx)?;
    core.finalize()
}

impl DaemonCore {
    fn run(&mut self, rx: mpsc::Receiver<CoreMsg>) -> Result<()> {
        let mut disconnected = false;
        loop {
            loop {
                match rx.try_recv() {
                    Ok(m) => {
                        // feed between arrivals so `max_queue` bounds the
                        // true backlog, not submissions a free slot is
                        // about to absorb
                        self.handle(m);
                        self.feed();
                    }
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        disconnected = true;
                        break;
                    }
                }
            }
            self.expire_deadlines();
            self.feed();
            if self.pc.active_slots() > 0 || self.pc.queue_len() > 0 {
                match self.pc.tick() {
                    Ok(done) => self.dispatch(done),
                    Err(e) => {
                        log::error!("daemon tick failed: {e}");
                        self.abort_all(&format!("engine failure: {e}"));
                        return Ok(());
                    }
                }
                continue;
            }
            if !self.pending.is_empty() {
                // feed() always moves work when slots are free, so a
                // non-empty backlog with an idle pipeline means the
                // next iteration will place it
                continue;
            }
            if self.draining || disconnected {
                return Ok(());
            }
            // idle: block until the next message (deadlines can only
            // expire while something is PENDING, and pending is empty)
            match rx.recv() {
                Ok(m) => self.handle(m),
                Err(_) => return Ok(()),
            }
        }
    }

    fn handle(&mut self, msg: CoreMsg) {
        match msg {
            CoreMsg::Submit { client, id, prompt, max_new, deadline_ms, reply } => {
                let now = self.pc.now_ms();
                let mut span = RequestSpan::start(id, client, prompt.len(), now);
                if self.draining || self.pending.len() >= self.cfg.max_queue {
                    self.busy_rejections += 1;
                    span.finish(SpanOutcome::Busy, now);
                    self.ring.push(span);
                    let _ = reply
                        .send(WireMsg::Busy { id, queue_depth: self.pending.len() as u32 });
                    return;
                }
                if prompt.is_empty() || max_new == 0 {
                    self.rejected += 1;
                    span.finish(SpanOutcome::Rejected, now);
                    self.ring.push(span);
                    let reason =
                        if prompt.is_empty() { "empty prompt" } else { "max_new == 0" };
                    let _ = reply.send(WireMsg::Error {
                        id,
                        code: ErrorCode::Rejected,
                        message: reason.to_string(),
                    });
                    return;
                }
                let deadline_ms = if deadline_ms == 0 {
                    self.cfg.default_deadline_ms
                } else {
                    deadline_ms
                };
                self.next_internal += 1;
                self.pending.push_back(Pending {
                    internal: self.next_internal,
                    client_req: id,
                    prompt,
                    max_new,
                    deadline_ms,
                    reply,
                    span,
                });
            }
            CoreMsg::Drain { reply } => {
                self.draining = true;
                self.drain_replies.push(reply);
            }
            CoreMsg::WireError => self.wire_errors += 1,
        }
    }

    /// Bounce pending requests whose deadline has passed (queue-level
    /// only — admitted requests run to completion).
    fn expire_deadlines(&mut self) {
        let now = self.pc.now_ms();
        let mut keep = VecDeque::with_capacity(self.pending.len());
        for mut p in self.pending.drain(..) {
            if p.deadline_ms > 0 && now - p.span.enqueue_ms >= p.deadline_ms as f64 {
                self.timeouts += 1;
                let _ = p.reply.send(WireMsg::Error {
                    id: p.client_req,
                    code: ErrorCode::Timeout,
                    message: format!("deadline {} ms expired in queue", p.deadline_ms),
                });
                p.span.finish(SpanOutcome::Timeout, now);
                self.ring.push(p.span);
            } else {
                keep.push_back(p);
            }
        }
        self.pending = keep;
    }

    /// Move backlog into the coordinator, one request per free slot —
    /// never more, so the coordinator's own queue stays shallow and
    /// deadline expiry keeps authority over everything still waiting.
    fn feed(&mut self) {
        let used = self.pc.active_slots() + self.pc.queue_len();
        let free = self.cfg.pipeline.batch.saturating_sub(used);
        for _ in 0..free {
            let Some(p) = self.pending.pop_front() else { break };
            self.pc.submit(Request {
                id: p.internal,
                prompt: p.prompt,
                max_new: p.max_new as usize,
                arrival_ms: p.span.enqueue_ms as u64,
            });
            self.live.insert(
                p.internal,
                Live {
                    client_req: p.client_req,
                    max_new: p.max_new,
                    reply: p.reply,
                    span: p.span,
                },
            );
        }
    }

    /// Stream this tick's tokens, then settle its completions. Reply
    /// sends to a hung-up client are ignored — a dropped connection
    /// doesn't cancel its generation.
    fn dispatch(&mut self, done: Vec<Completion>) {
        let now = self.pc.now_ms();
        for TokenEvent { id, index, token } in self.pc.take_token_events() {
            if let Some(l) = self.live.get_mut(&id) {
                l.span.note_token(index, now);
                let _ = l.reply.send(WireMsg::Token {
                    id: l.client_req,
                    index: index as u32,
                    token,
                });
            }
        }
        for c in done {
            let Some(mut l) = self.live.remove(&c.id) else { continue };
            let finish = if c.tokens.len() >= l.max_new as usize {
                FinishReason::Complete
            } else {
                FinishReason::Capacity
            };
            l.span.finish(SpanOutcome::Complete, now);
            let _ = l.reply.send(WireMsg::Done {
                id: l.client_req,
                finish,
                tokens: c.tokens.len() as u32,
                queue_ms: c.queue_ms,
                decode_ms: c.decode_ms,
                latency_ms: c.latency_ms,
            });
            self.ring.push(l.span);
        }
    }

    /// Fatal engine error: every outstanding request gets a typed
    /// internal `Error`, then the daemon shuts down with the failure
    /// counted (the tick already bumped `internal_errors`).
    fn abort_all(&mut self, why: &str) {
        let now = self.pc.now_ms();
        let mut outstanding: Vec<(u64, mpsc::Sender<WireMsg>, RequestSpan)> = Vec::new();
        for (_, l) in std::mem::take(&mut self.live) {
            outstanding.push((l.client_req, l.reply, l.span));
        }
        for p in self.pending.drain(..) {
            outstanding.push((p.client_req, p.reply, p.span));
        }
        for (id, reply, mut span) in outstanding {
            let _ = reply.send(WireMsg::Error {
                id,
                code: ErrorCode::Internal,
                message: why.to_string(),
            });
            span.finish(SpanOutcome::Error, now);
            self.ring.push(span);
        }
    }

    fn finalize(mut self) -> Result<DaemonReport> {
        // ack drains FIRST so no waiter can hang on a finish error
        for r in self.drain_replies.drain(..) {
            let _ = r.send(WireMsg::Drain);
        }
        let rep = self.pc.finish()?;
        let mut metrics = rep.metrics.clone();
        metrics.rejected += self.rejected + self.busy_rejections;
        metrics.internal_errors += self.wire_errors;
        metrics.timeouts += self.timeouts;
        metrics.phases = phase_stats(&self.ring);
        if let Some(path) = &self.cfg.trace_out {
            if let Err(e) = self.ring.write_jsonl(path) {
                log::error!("span trace dump failed: {e}");
            }
        }
        Ok(DaemonReport {
            metrics,
            completions: rep.completions,
            steps: rep.steps,
            shards: rep.shards,
            busy_rejections: self.busy_rejections,
            timeouts: self.timeouts,
            wire_errors: self.wire_errors,
            spans: self.ring,
        })
    }
}

/// A running daemon: the TCP accept loop + the core, with handles to
/// drain and collect the final report.
pub struct Daemon {
    addr: String,
    tx: mpsc::Sender<CoreMsg>,
    core: JoinHandle<Result<DaemonReport>>,
    accept: JoinHandle<()>,
    stop: Arc<AtomicBool>,
}

impl Daemon {
    /// Bind `cfg.listen`, spawn the core and the accept loop, return
    /// immediately. `addr()` reports the bound address (so `:0` works
    /// for tests).
    pub fn start(cfg: DaemonConfig, source: PipelineSource) -> Result<Daemon> {
        let listener =
            TcpListener::bind(&cfg.listen).map_err(|e| anyhow!("bind {}: {e}", cfg.listen))?;
        let addr = listener
            .local_addr()
            .map_err(|e| anyhow!("local_addr on {}: {e}", cfg.listen))?
            .to_string();
        let (tx, rx) = mpsc::channel();
        let core =
            crate::util::pool::spawn_worker("daemon-core", move || run_core(cfg, &source, rx));
        let stop = Arc::new(AtomicBool::new(false));
        let (stop2, tx2) = (stop.clone(), tx.clone());
        let accept = crate::util::pool::spawn_worker("daemon-accept", move || {
            accept_loop(listener, tx2, stop2)
        });
        Ok(Daemon { addr, tx, core, accept, stop })
    }

    /// The bound `host:port`.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Server-side graceful drain: stop admitting, finish in-flight
    /// generations, stream the tails, then collect the report.
    pub fn finish(self) -> Result<DaemonReport> {
        let Daemon { addr, tx, core, accept, stop } = self;
        let (rtx, rrx) = mpsc::channel();
        if tx.send(CoreMsg::Drain { reply: rtx }).is_ok() {
            // core gone before acking == already drained; proceed
            let _ = rrx.recv();
        }
        shutdown_accept(&addr, &stop, accept);
        match core.join() {
            Ok(r) => r,
            Err(_) => bail!("daemon core panicked"),
        }
    }

    /// Wait for a CLIENT-driven drain ([`drain_daemon`] /
    /// `higgs request --drain`) to complete, then collect the report.
    pub fn wait(self) -> Result<DaemonReport> {
        let Daemon { addr, tx: _tx, core, accept, stop } = self;
        let report = match core.join() {
            Ok(r) => r,
            Err(_) => Err(anyhow!("daemon core panicked")),
        };
        shutdown_accept(&addr, &stop, accept);
        report
    }
}

/// Wake the blocking `accept()` with a probe connection (it sees the
/// stop flag and exits) and join the loop.
fn shutdown_accept(addr: &str, stop: &AtomicBool, accept: JoinHandle<()>) {
    stop.store(true, Ordering::SeqCst);
    let _ = TcpStream::connect(addr);
    let _ = accept.join();
}

fn accept_loop(listener: TcpListener, tx: mpsc::Sender<CoreMsg>, stop: Arc<AtomicBool>) {
    let mut client = 0u64;
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                client += 1;
                let (ctx, cid) = (tx.clone(), client);
                // detached: a connection lives as long as its client
                let _ = crate::util::pool::spawn_worker(&format!("daemon-conn-{cid}"), move || {
                    if let Err(e) = serve_connection(stream, ctx, cid) {
                        log::warn!("connection {cid} closed: {e}");
                    }
                });
            }
            Err(e) => {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                log::error!("daemon accept failed: {e}");
                break;
            }
        }
    }
}

/// One client connection: read wire messages, forward submits to the
/// core, stream each request's replies back until terminal. A corrupt
/// frame reports [`CoreMsg::WireError`] and closes THIS connection —
/// the daemon keeps serving.
fn serve_connection(mut stream: TcpStream, tx: mpsc::Sender<CoreMsg>, client: u64) -> Result<()> {
    loop {
        let msg = match read_msg(&mut stream) {
            Ok(Some(m)) => m,
            Ok(None) => return Ok(()),
            Err(e) => {
                let _ = tx.send(CoreMsg::WireError);
                bail!("corrupt frame: {e}");
            }
        };
        match msg {
            WireMsg::Submit { id, prompt, max_new, deadline_ms } => {
                let (rtx, rrx) = mpsc::channel();
                let sent = tx.send(CoreMsg::Submit {
                    client,
                    id,
                    prompt,
                    max_new,
                    deadline_ms,
                    reply: rtx,
                });
                if sent.is_err() {
                    // core already shut down: typed bounce, clean close
                    let _ = write_msg(&mut stream, &WireMsg::Busy { id, queue_depth: 0 });
                    return Ok(());
                }
                let mut terminal = false;
                for m in rrx.iter() {
                    let is_terminal = matches!(
                        m,
                        WireMsg::Done { .. } | WireMsg::Error { .. } | WireMsg::Busy { .. }
                    );
                    write_msg(&mut stream, &m)?;
                    if is_terminal {
                        terminal = true;
                        break;
                    }
                }
                if !terminal {
                    write_msg(
                        &mut stream,
                        &WireMsg::Error {
                            id,
                            code: ErrorCode::Internal,
                            message: "daemon core exited mid-request".to_string(),
                        },
                    )?;
                    return Ok(());
                }
            }
            WireMsg::Drain => {
                let (rtx, rrx) = mpsc::channel();
                if tx.send(CoreMsg::Drain { reply: rtx }).is_ok() {
                    // blocks until every in-flight request completed
                    let _ = rrx.recv();
                }
                write_msg(&mut stream, &WireMsg::Drain)?;
                return Ok(());
            }
            other => {
                let _ = tx.send(CoreMsg::WireError);
                bail!("client sent server-only message kind {}", other.kind());
            }
        }
    }
}

/// One client-side request for [`request_many`].
#[derive(Clone, Debug)]
pub struct ClientRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: u32,
    /// 0 = use the daemon's default
    pub deadline_ms: u32,
}

/// What one request resolved to, client-side.
#[derive(Clone, Debug, PartialEq)]
pub enum ClientOutcome {
    Done {
        tokens: Vec<i32>,
        finish: FinishReason,
        queue_ms: f64,
        decode_ms: f64,
        latency_ms: f64,
    },
    Busy { queue_depth: u32 },
    Failed { code: ErrorCode, message: String },
}

/// Submit `reqs` sequentially over ONE connection, validating the
/// stream as it arrives (ids match, token indices are gapless, the
/// terminal count equals the streamed count). The client side of
/// `higgs request` and the smoke/bench harnesses.
pub fn request_many(addr: &str, reqs: &[ClientRequest]) -> Result<Vec<(u64, ClientOutcome)>> {
    let mut stream = TcpStream::connect(addr).map_err(|e| anyhow!("connect {addr}: {e}"))?;
    let mut out = Vec::with_capacity(reqs.len());
    for r in reqs {
        write_msg(
            &mut stream,
            &WireMsg::Submit {
                id: r.id,
                prompt: r.prompt.clone(),
                max_new: r.max_new,
                deadline_ms: r.deadline_ms,
            },
        )?;
        let mut tokens: Vec<i32> = Vec::new();
        loop {
            let Some(m) = read_msg(&mut stream)? else {
                bail!("daemon closed mid-request {}", r.id)
            };
            match m {
                WireMsg::Token { id, index, token } => {
                    ensure!(id == r.id, "token for request {id}, expected {}", r.id);
                    ensure!(
                        index as usize == tokens.len(),
                        "token index {index} out of order (have {})",
                        tokens.len()
                    );
                    tokens.push(token);
                }
                WireMsg::Done { id, finish, tokens: n, queue_ms, decode_ms, latency_ms } => {
                    ensure!(id == r.id, "Done for request {id}, expected {}", r.id);
                    ensure!(
                        n as usize == tokens.len(),
                        "Done says {n} tokens, streamed {}",
                        tokens.len()
                    );
                    out.push((
                        r.id,
                        ClientOutcome::Done { tokens, finish, queue_ms, decode_ms, latency_ms },
                    ));
                    break;
                }
                WireMsg::Busy { id, queue_depth } => {
                    ensure!(id == r.id, "Busy for request {id}, expected {}", r.id);
                    out.push((r.id, ClientOutcome::Busy { queue_depth }));
                    break;
                }
                WireMsg::Error { id, code, message } => {
                    ensure!(id == r.id, "Error for request {id}, expected {}", r.id);
                    out.push((r.id, ClientOutcome::Failed { code, message }));
                    break;
                }
                other => bail!("unexpected message kind {} from daemon", other.kind()),
            }
        }
    }
    Ok(out)
}

/// Ask a daemon to drain gracefully and wait for the ack.
pub fn drain_daemon(addr: &str) -> Result<()> {
    let mut stream = TcpStream::connect(addr).map_err(|e| anyhow!("connect {addr}: {e}"))?;
    write_msg(&mut stream, &WireMsg::Drain)?;
    match read_msg(&mut stream)? {
        Some(WireMsg::Drain) => Ok(()),
        Some(m) => bail!("unexpected message kind {} while draining", m.kind()),
        None => bail!("daemon closed before acking the drain"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_cfg() -> DaemonConfig {
        DaemonConfig {
            max_queue: 4,
            pipeline: PipelineConfig {
                shards: 1,
                batch: 2,
                seq: 24,
                vocab: 61,
                layers: 3,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    fn submit(
        tx: &mpsc::Sender<CoreMsg>,
        id: u64,
        prompt: Vec<i32>,
        max_new: u32,
        deadline_ms: u32,
    ) -> mpsc::Receiver<WireMsg> {
        let (rtx, rrx) = mpsc::channel();
        tx.send(CoreMsg::Submit { client: 0, id, prompt, max_new, deadline_ms, reply: rtx })
            .unwrap();
        rrx
    }

    fn collect_terminal(rx: &mpsc::Receiver<WireMsg>) -> (Vec<i32>, WireMsg) {
        let mut tokens = Vec::new();
        loop {
            let m = rx.recv().unwrap();
            match m {
                WireMsg::Token { index, token, .. } => {
                    assert_eq!(index as usize, tokens.len());
                    tokens.push(token);
                }
                other => return (tokens, other),
            }
        }
    }

    #[test]
    fn core_drains_in_flight_and_bounces_late_submits() {
        let (tx, rx) = mpsc::channel();
        let r1 = submit(&tx, 11, vec![1, 2, 3], 4, 0);
        let r2 = submit(&tx, 12, vec![4, 5], 3, 0);
        let (dtx, drx) = mpsc::channel();
        tx.send(CoreMsg::Drain { reply: dtx }).unwrap();
        // after the drain request: typed Busy, not silence
        let r3 = submit(&tx, 13, vec![9], 2, 0);
        drop(tx);
        let rep = run_core(test_cfg(), &PipelineSource::Synthetic, rx).unwrap();
        let (t1, done1) = collect_terminal(&r1);
        assert_eq!(t1.len(), 4);
        assert!(matches!(done1, WireMsg::Done { id: 11, finish: FinishReason::Complete, .. }));
        let (t2, done2) = collect_terminal(&r2);
        assert_eq!(t2.len(), 3);
        assert!(matches!(done2, WireMsg::Done { id: 12, .. }));
        let (t3, late) = collect_terminal(&r3);
        assert!(t3.is_empty());
        assert!(matches!(late, WireMsg::Busy { id: 13, .. }));
        assert_eq!(drx.recv().unwrap(), WireMsg::Drain);
        assert_eq!(rep.completions.len(), 2);
        assert_eq!(rep.busy_rejections, 1);
        assert_eq!(rep.metrics.rejected, 1);
        assert!(!rep.metrics.phases.is_empty());
    }

    #[test]
    fn queued_deadline_expires_on_virtual_clock() {
        // batch=1: the long request holds the one slot while the
        // deadlined request waits in the daemon queue
        let mut cfg = test_cfg();
        cfg.pipeline.batch = 1;
        let (tx, rx) = mpsc::channel();
        let r1 = submit(&tx, 1, vec![1, 2, 3], 12, 0);
        let r2 = submit(&tx, 2, vec![4, 5], 2, 3);
        drop(tx);
        let rep = run_core(cfg, &PipelineSource::Synthetic, rx).unwrap();
        let (t1, done1) = collect_terminal(&r1);
        assert_eq!(t1.len(), 12);
        assert!(matches!(done1, WireMsg::Done { id: 1, .. }));
        let (t2, err2) = collect_terminal(&r2);
        assert!(t2.is_empty());
        assert!(
            matches!(err2, WireMsg::Error { id: 2, code: ErrorCode::Timeout, .. }),
            "wanted a typed timeout, got {err2:?}"
        );
        assert_eq!(rep.timeouts, 1);
        assert_eq!(rep.metrics.timeouts, 1);
        assert_eq!(rep.completions.len(), 1);
        // the timed-out span is in the ring with its outcome
        assert!(rep
            .spans
            .iter()
            .any(|s| s.id == 2 && s.outcome == SpanOutcome::Timeout && s.admit_ms.is_none()));
    }

    #[test]
    fn invalid_submits_get_typed_rejections() {
        let (tx, rx) = mpsc::channel();
        let r1 = submit(&tx, 1, vec![], 3, 0);
        let r2 = submit(&tx, 2, vec![1], 0, 0);
        drop(tx);
        let rep = run_core(test_cfg(), &PipelineSource::Synthetic, rx).unwrap();
        for r in [r1, r2] {
            let (toks, term) = collect_terminal(&r);
            assert!(toks.is_empty());
            assert!(matches!(term, WireMsg::Error { code: ErrorCode::Rejected, .. }));
        }
        assert_eq!(rep.metrics.rejected, 2);
        assert_eq!(rep.completions.len(), 0);
    }

    #[test]
    fn bounded_queue_bounces_overflow_as_busy() {
        let mut cfg = test_cfg();
        cfg.pipeline.batch = 1;
        cfg.max_queue = 1;
        let (tx, rx) = mpsc::channel();
        // one running, one queued, the third overflows
        let _r1 = submit(&tx, 1, vec![1, 2], 6, 0);
        let _r2 = submit(&tx, 2, vec![3], 2, 0);
        let r3 = submit(&tx, 3, vec![4], 2, 0);
        drop(tx);
        let rep = run_core(cfg, &PipelineSource::Synthetic, rx).unwrap();
        let (_, term) = collect_terminal(&r3);
        assert!(matches!(term, WireMsg::Busy { id: 3, queue_depth: 1 }), "got {term:?}");
        assert_eq!(rep.busy_rejections, 1);
        assert_eq!(rep.completions.len(), 2);
    }

    #[test]
    fn tcp_daemon_serves_and_drains() {
        let daemon = Daemon::start(test_cfg(), PipelineSource::Synthetic).unwrap();
        let reqs = vec![
            ClientRequest { id: 1, prompt: vec![1, 2, 3], max_new: 4, deadline_ms: 0 },
            ClientRequest { id: 2, prompt: vec![7], max_new: 3, deadline_ms: 0 },
        ];
        let got = request_many(daemon.addr(), &reqs).unwrap();
        assert_eq!(got.len(), 2);
        for (id, outcome) in &got {
            match outcome {
                ClientOutcome::Done { tokens, finish, .. } => {
                    let want = reqs.iter().find(|r| r.id == *id).unwrap().max_new as usize;
                    assert_eq!(tokens.len(), want);
                    assert_eq!(*finish, FinishReason::Complete);
                }
                other => panic!("request {id} got {other:?}"),
            }
        }
        let rep = daemon.finish().unwrap();
        assert_eq!(rep.completions.len(), 2);
        assert_eq!(rep.wire_errors, 0);
        assert_eq!(rep.metrics.internal_errors, 0);
    }

    #[test]
    fn corrupt_client_frame_closes_connection_daemon_survives() {
        let daemon = Daemon::start(test_cfg(), PipelineSource::Synthetic).unwrap();
        // a raw garbage burst on one connection
        {
            use std::io::{Read as _, Write as _};
            let mut s = TcpStream::connect(daemon.addr()).unwrap();
            s.write_all(&[0x13, 0x00, 0x00, 0x00, 0xde, 0xad, 0xbe, 0xef]).unwrap();
            s.shutdown(std::net::Shutdown::Write).unwrap();
            // the daemon closes the corrupt connection; seeing EOF here
            // guarantees its WireError already reached the core
            let mut buf = [0u8; 8];
            assert_eq!(s.read(&mut buf).unwrap_or(0), 0);
        }
        // the daemon still serves fresh connections afterwards
        let reqs = vec![ClientRequest { id: 9, prompt: vec![5, 6], max_new: 2, deadline_ms: 0 }];
        let got = request_many(daemon.addr(), &reqs).unwrap();
        assert!(matches!(got[0].1, ClientOutcome::Done { .. }));
        // drain via the client path this time
        drain_daemon(daemon.addr()).unwrap();
        let rep = daemon.wait().unwrap();
        assert_eq!(rep.completions.len(), 1);
        assert_eq!(rep.wire_errors, 1);
        assert_eq!(rep.metrics.internal_errors, 1);
    }
}
