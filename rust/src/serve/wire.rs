//! Client-facing request wire protocol for the serving daemon
//! (PERF.md §13): length-prefixed little-endian messages with an
//! FNV-1a trailer — the same framing discipline as
//! [`ActivationFrame`](super::transport::ActivationFrame), so a flipped
//! byte anywhere in a message is caught at parse time, never decoded
//! into a garbage request.
//!
//! Message kinds:
//!   * `Submit` — client → daemon: prompt tokens + `max_new` +
//!     an optional per-request deadline (0 = none).
//!   * `Token` — daemon → client: one streamed token with its index.
//!   * `Done` — daemon → client: terminal success, with the finish
//!     reason and the queue/decode/total latency split.
//!   * `Error` — daemon → client: terminal failure with a typed code.
//!   * `Busy` — daemon → client: typed backpressure rejection (queue
//!     full or draining), carrying the queue depth observed.
//!   * `Drain` — client → daemon requests graceful drain; daemon →
//!     client acknowledges once every in-flight request has completed.
//!
//! Parsing is panic-free: truncation, trailing garbage, checksum
//! mismatches, unknown kinds/codes, and absurd length prefixes are all
//! `Err`, never a panic — a corrupt client frame must not tear down
//! the daemon.
//!
//! This module is under the `wall-clock` audit rule: the protocol
//! carries durations measured elsewhere (on `serve::Clock`) but never
//! reads time itself.

use anyhow::{anyhow, bail, ensure, Result};
use std::io::{Read, Write};

/// Message kind bytes on the wire.
pub const MSG_SUBMIT: u8 = 0;
pub const MSG_TOKEN: u8 = 1;
pub const MSG_DONE: u8 = 2;
pub const MSG_ERROR: u8 = 3;
pub const MSG_BUSY: u8 = 4;
pub const MSG_DRAIN: u8 = 5;

/// Wire overhead around the payload: u32 length prefix + u64 FNV
/// trailer (identical to the activation-frame transport).
pub const WIRE_OVERHEAD: usize = 12;
/// Upper bound on an accepted payload (16 MiB) — a corrupt length
/// prefix must produce an error, not an OOM-sized allocation.
const MAX_PAYLOAD: usize = 16 << 20;
/// Upper bound on a `Submit` prompt (tokens). Generous for any real
/// context window while keeping a corrupt count from allocating GiBs.
const MAX_PROMPT: usize = 1 << 20;
/// Upper bound on an `Error` message string (bytes).
const MAX_MESSAGE: usize = 1 << 16;

/// Why a generation stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Produced the requested `max_new` tokens.
    Complete,
    /// Hit the KV sequence capacity before `max_new`.
    Capacity,
}

impl FinishReason {
    fn code(self) -> u8 {
        match self {
            FinishReason::Complete => 0,
            FinishReason::Capacity => 1,
        }
    }

    fn from_code(c: u8) -> Result<FinishReason> {
        match c {
            0 => Ok(FinishReason::Complete),
            1 => Ok(FinishReason::Capacity),
            _ => bail!("unknown finish reason code {c}"),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            FinishReason::Complete => "complete",
            FinishReason::Capacity => "capacity",
        }
    }
}

/// Typed failure codes on `Error` messages.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// The request's deadline expired before it was admitted.
    Timeout,
    /// The request was invalid (empty prompt, zero `max_new`, …).
    Rejected,
    /// The engine failed; the daemon's `internal_errors` counter grew.
    Internal,
}

impl ErrorCode {
    fn code(self) -> u8 {
        match self {
            ErrorCode::Timeout => 0,
            ErrorCode::Rejected => 1,
            ErrorCode::Internal => 2,
        }
    }

    fn from_code(c: u8) -> Result<ErrorCode> {
        match c {
            0 => Ok(ErrorCode::Timeout),
            1 => Ok(ErrorCode::Rejected),
            2 => Ok(ErrorCode::Internal),
            _ => bail!("unknown error code {c}"),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            ErrorCode::Timeout => "timeout",
            ErrorCode::Rejected => "rejected",
            ErrorCode::Internal => "internal",
        }
    }
}

/// One protocol message. `id` is always the CLIENT's request id — the
/// daemon maps it to its internal pipeline id and back, so a client
/// multiplexing requests over one connection can match replies.
#[derive(Clone, Debug, PartialEq)]
pub enum WireMsg {
    Submit { id: u64, prompt: Vec<i32>, max_new: u32, deadline_ms: u32 },
    Token { id: u64, index: u32, token: i32 },
    Done { id: u64, finish: FinishReason, tokens: u32, queue_ms: f64, decode_ms: f64, latency_ms: f64 },
    Error { id: u64, code: ErrorCode, message: String },
    Busy { id: u64, queue_depth: u32 },
    Drain,
}

impl WireMsg {
    pub fn kind(&self) -> u8 {
        match self {
            WireMsg::Submit { .. } => MSG_SUBMIT,
            WireMsg::Token { .. } => MSG_TOKEN,
            WireMsg::Done { .. } => MSG_DONE,
            WireMsg::Error { .. } => MSG_ERROR,
            WireMsg::Busy { .. } => MSG_BUSY,
            WireMsg::Drain => MSG_DRAIN,
        }
    }

    fn payload(&self) -> Vec<u8> {
        let mut p = vec![self.kind()];
        match self {
            WireMsg::Submit { id, prompt, max_new, deadline_ms } => {
                p.extend_from_slice(&id.to_le_bytes());
                p.extend_from_slice(&(prompt.len() as u32).to_le_bytes());
                for t in prompt {
                    p.extend_from_slice(&t.to_le_bytes());
                }
                p.extend_from_slice(&max_new.to_le_bytes());
                p.extend_from_slice(&deadline_ms.to_le_bytes());
            }
            WireMsg::Token { id, index, token } => {
                p.extend_from_slice(&id.to_le_bytes());
                p.extend_from_slice(&index.to_le_bytes());
                p.extend_from_slice(&token.to_le_bytes());
            }
            WireMsg::Done { id, finish, tokens, queue_ms, decode_ms, latency_ms } => {
                p.extend_from_slice(&id.to_le_bytes());
                p.push(finish.code());
                p.extend_from_slice(&tokens.to_le_bytes());
                p.extend_from_slice(&queue_ms.to_le_bytes());
                p.extend_from_slice(&decode_ms.to_le_bytes());
                p.extend_from_slice(&latency_ms.to_le_bytes());
            }
            WireMsg::Error { id, code, message } => {
                p.extend_from_slice(&id.to_le_bytes());
                p.push(code.code());
                p.extend_from_slice(&(message.len() as u32).to_le_bytes());
                p.extend_from_slice(message.as_bytes());
            }
            WireMsg::Busy { id, queue_depth } => {
                p.extend_from_slice(&id.to_le_bytes());
                p.extend_from_slice(&queue_depth.to_le_bytes());
            }
            WireMsg::Drain => {}
        }
        p
    }

    /// Total bytes this message occupies on the wire.
    pub fn wire_len(&self) -> usize {
        self.payload().len() + WIRE_OVERHEAD
    }

    /// Serialize to the full wire form: `len:u32 LE` over the payload,
    /// the payload, then `fnv1a(payload):u64 LE`.
    pub fn to_bytes(&self) -> Vec<u8> {
        let payload = self.payload();
        let mut out = Vec::with_capacity(payload.len() + WIRE_OVERHEAD);
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&payload);
        let fnv = crate::util::fnv1a(payload.iter().copied());
        out.extend_from_slice(&fnv.to_le_bytes());
        out
    }

    /// Parse a full wire message (length prefix + payload + FNV
    /// trailer). Every failure mode — truncation, trailing garbage, a
    /// checksum mismatch, unknown kinds or codes — is an `Err`, never
    /// a panic.
    pub fn from_bytes(buf: &[u8]) -> Result<WireMsg> {
        let (len_b, rest) =
            take(buf, 4).map_err(|_| anyhow!("message shorter than its length prefix"))?;
        let plen = u32::from_le_bytes(arr4(len_b)?) as usize;
        ensure!(plen <= MAX_PAYLOAD, "message payload length {plen} exceeds the {MAX_PAYLOAD} cap");
        ensure!(
            rest.len() == plen + 8,
            "message length prefix says {plen} payload bytes, got {} (+8 trailer expected)",
            rest.len().saturating_sub(8)
        );
        let (payload, trailer) = take(rest, plen)?;
        let fnv_want = u64::from_le_bytes(arr8(trailer)?);
        let fnv_got = crate::util::fnv1a(payload.iter().copied());
        ensure!(
            fnv_got == fnv_want,
            "message checksum mismatch: computed {fnv_got:#018x}, trailer {fnv_want:#018x}"
        );
        Self::from_payload(payload)
    }

    fn from_payload(payload: &[u8]) -> Result<WireMsg> {
        let (kind_b, p) = take(payload, 1)?;
        let kind = kind_b.first().copied().ok_or_else(|| anyhow!("empty message payload"))?;
        let (msg, p) = match kind {
            MSG_SUBMIT => {
                let (id, p) = take_u64(p)?;
                let (n, p) = take_u32(p)?;
                let n = n as usize;
                ensure!(n <= MAX_PROMPT, "prompt length {n} exceeds the {MAX_PROMPT} cap");
                let (prompt_b, p) = take(p, n * 4)?;
                let mut prompt = Vec::with_capacity(n);
                for c in prompt_b.chunks_exact(4) {
                    prompt.push(i32::from_le_bytes(arr4(c)?));
                }
                let (max_new, p) = take_u32(p)?;
                let (deadline_ms, p) = take_u32(p)?;
                (WireMsg::Submit { id, prompt, max_new, deadline_ms }, p)
            }
            MSG_TOKEN => {
                let (id, p) = take_u64(p)?;
                let (index, p) = take_u32(p)?;
                let (token, p) = take_u32(p)?;
                (WireMsg::Token { id, index, token: token as i32 }, p)
            }
            MSG_DONE => {
                let (id, p) = take_u64(p)?;
                let (fin_b, p) = take(p, 1)?;
                let finish = FinishReason::from_code(
                    fin_b.first().copied().ok_or_else(|| anyhow!("missing finish reason"))?,
                )?;
                let (tokens, p) = take_u32(p)?;
                let (queue_ms, p) = take_f64(p)?;
                let (decode_ms, p) = take_f64(p)?;
                let (latency_ms, p) = take_f64(p)?;
                (WireMsg::Done { id, finish, tokens, queue_ms, decode_ms, latency_ms }, p)
            }
            MSG_ERROR => {
                let (id, p) = take_u64(p)?;
                let (code_b, p) = take(p, 1)?;
                let code = ErrorCode::from_code(
                    code_b.first().copied().ok_or_else(|| anyhow!("missing error code"))?,
                )?;
                let (n, p) = take_u32(p)?;
                let n = n as usize;
                ensure!(n <= MAX_MESSAGE, "error message length {n} exceeds the {MAX_MESSAGE} cap");
                let (msg_b, p) = take(p, n)?;
                let message = std::str::from_utf8(msg_b)
                    .map_err(|_| anyhow!("error message is not valid UTF-8"))?
                    .to_string();
                (WireMsg::Error { id, code, message }, p)
            }
            MSG_BUSY => {
                let (id, p) = take_u64(p)?;
                let (queue_depth, p) = take_u32(p)?;
                (WireMsg::Busy { id, queue_depth }, p)
            }
            MSG_DRAIN => (WireMsg::Drain, p),
            _ => bail!("unknown message kind {kind}"),
        };
        ensure!(p.is_empty(), "message has {} trailing payload bytes", p.len());
        Ok(msg)
    }
}

/// Write one message to a byte stream (a `TcpStream` in the daemon,
/// anything `Write` in tests).
pub fn write_msg<W: Write>(w: &mut W, msg: &WireMsg) -> Result<()> {
    let wire = msg.to_bytes();
    w.write_all(&wire).map_err(|e| anyhow!("wire write: {e}"))?;
    Ok(())
}

/// Read one message from a byte stream. Returns `Ok(None)` on a CLEAN
/// end-of-stream — zero bytes available at the first length byte, i.e.
/// the peer closed between messages. EOF anywhere mid-frame is
/// corruption and returns `Err`, as does any parse failure.
pub fn read_msg<R: Read>(r: &mut R) -> Result<Option<WireMsg>> {
    let mut len_b = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        let n = r.read(&mut len_b[got..]).map_err(|e| anyhow!("wire read (length): {e}"))?;
        if n == 0 {
            ensure!(got == 0, "peer closed mid-frame ({got} of 4 length bytes)");
            return Ok(None);
        }
        got += n;
    }
    let plen = u32::from_le_bytes(len_b) as usize;
    ensure!(plen <= MAX_PAYLOAD, "message payload length {plen} exceeds the {MAX_PAYLOAD} cap");
    let mut rest = vec![0u8; plen + 8];
    r.read_exact(&mut rest).map_err(|e| anyhow!("wire read (payload): {e}"))?;
    let mut wire = Vec::with_capacity(4 + rest.len());
    wire.extend_from_slice(&len_b);
    wire.extend_from_slice(&rest);
    WireMsg::from_bytes(&wire).map(Some)
}

fn take(buf: &[u8], n: usize) -> Result<(&[u8], &[u8])> {
    ensure!(buf.len() >= n, "message truncated: wanted {n} bytes, have {}", buf.len());
    Ok(buf.split_at(n))
}

fn take_u32(buf: &[u8]) -> Result<(u32, &[u8])> {
    let (b, rest) = take(buf, 4)?;
    Ok((u32::from_le_bytes(arr4(b)?), rest))
}

fn take_u64(buf: &[u8]) -> Result<(u64, &[u8])> {
    let (b, rest) = take(buf, 8)?;
    Ok((u64::from_le_bytes(arr8(b)?), rest))
}

fn take_f64(buf: &[u8]) -> Result<(f64, &[u8])> {
    let (b, rest) = take(buf, 8)?;
    Ok((f64::from_le_bytes(arr8(b)?), rest))
}

fn arr4(b: &[u8]) -> Result<[u8; 4]> {
    b.try_into().map_err(|_| anyhow!("message field: expected 4 bytes, got {}", b.len()))
}

fn arr8(b: &[u8]) -> Result<[u8; 8]> {
    b.try_into().map_err(|_| anyhow!("message field: expected 8 bytes, got {}", b.len()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_kinds() -> Vec<WireMsg> {
        vec![
            WireMsg::Submit { id: 7, prompt: vec![1, -2, 3, i32::MAX], max_new: 9, deadline_ms: 250 },
            WireMsg::Token { id: 7, index: 3, token: -41 },
            WireMsg::Done {
                id: 7,
                finish: FinishReason::Capacity,
                tokens: 4,
                queue_ms: 1.5,
                decode_ms: 8.25,
                latency_ms: 9.75,
            },
            WireMsg::Error { id: 7, code: ErrorCode::Timeout, message: "deadline 250ms".into() },
            WireMsg::Busy { id: 7, queue_depth: 64 },
            WireMsg::Drain,
        ]
    }

    #[test]
    fn roundtrip_every_kind() {
        for msg in all_kinds() {
            let wire = msg.to_bytes();
            assert_eq!(wire.len(), msg.wire_len());
            let back = WireMsg::from_bytes(&wire).unwrap();
            assert_eq!(back, msg, "roundtrip drift for kind {}", msg.kind());
        }
    }

    #[test]
    fn corruption_and_truncation_error_not_panic() {
        for msg in all_kinds() {
            let wire = msg.to_bytes();
            for i in 0..wire.len() {
                let mut bad = wire.clone();
                bad[i] ^= 0x40;
                assert!(
                    WireMsg::from_bytes(&bad).is_err(),
                    "kind {}: flip at byte {i} accepted",
                    msg.kind()
                );
            }
            for n in 0..wire.len() {
                assert!(
                    WireMsg::from_bytes(&wire[..n]).is_err(),
                    "kind {}: truncation to {n} accepted",
                    msg.kind()
                );
            }
            let mut long = wire.clone();
            long.push(0);
            assert!(WireMsg::from_bytes(&long).is_err(), "trailing garbage accepted");
        }
    }

    #[test]
    fn absurd_length_prefix_errors_without_allocating() {
        let mut wire = WireMsg::Drain.to_bytes();
        wire[..4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(WireMsg::from_bytes(&wire).is_err());
    }

    #[test]
    fn unknown_kind_and_codes_rejected() {
        // kind byte lives at wire offset 4; re-seal the checksum so
        // ONLY the kind check can catch it
        let reseal = |wire: &mut Vec<u8>| {
            let plen = wire.len() - WIRE_OVERHEAD;
            let fnv = crate::util::fnv1a(wire[4..4 + plen].iter().copied());
            let at = 4 + plen;
            wire[at..at + 8].copy_from_slice(&fnv.to_le_bytes());
        };
        let mut wire = WireMsg::Drain.to_bytes();
        wire[4] = 99;
        reseal(&mut wire);
        assert!(WireMsg::from_bytes(&wire).is_err(), "unknown kind accepted");
        // finish-reason byte of Done lives at payload offset 9 → wire 13
        let done = WireMsg::Done {
            id: 1,
            finish: FinishReason::Complete,
            tokens: 1,
            queue_ms: 0.0,
            decode_ms: 0.0,
            latency_ms: 0.0,
        };
        let mut wire = done.to_bytes();
        wire[13] = 99;
        reseal(&mut wire);
        assert!(WireMsg::from_bytes(&wire).is_err(), "unknown finish reason accepted");
        let err = WireMsg::Error { id: 1, code: ErrorCode::Internal, message: String::new() };
        let mut wire = err.to_bytes();
        wire[13] = 99;
        reseal(&mut wire);
        assert!(WireMsg::from_bytes(&wire).is_err(), "unknown error code accepted");
    }

    #[test]
    fn stream_read_write_and_clean_eof() {
        let mut buf = Vec::new();
        for msg in all_kinds() {
            write_msg(&mut buf, &msg).unwrap();
        }
        let mut cur = std::io::Cursor::new(buf.clone());
        for msg in all_kinds() {
            assert_eq!(read_msg(&mut cur).unwrap(), Some(msg));
        }
        // clean EOF between messages → Ok(None)
        assert!(read_msg(&mut cur).unwrap().is_none());
        // EOF mid-frame → Err, not Ok(None)
        let mut cut = std::io::Cursor::new(buf[..buf.len() - 3].to_vec());
        for _ in 0..all_kinds().len() - 1 {
            read_msg(&mut cut).unwrap();
        }
        assert!(read_msg(&mut cut).is_err(), "mid-frame EOF must be an error");
        // EOF inside the length prefix itself → Err
        let mut cut = std::io::Cursor::new(all_kinds()[0].to_bytes()[..2].to_vec());
        assert!(read_msg(&mut cut).is_err());
    }

    #[test]
    fn empty_input_is_clean_eof() {
        let mut cur = std::io::Cursor::new(Vec::<u8>::new());
        assert!(read_msg(&mut cur).unwrap().is_none());
    }
}
