//! KV-cache block manager — the vLLM-style paged allocator of the
//! serving coordinator.
//!
//! The fixed-shape HLO executables own the *contents* of the KV tensors;
//! this manager owns the *accounting*: slots, logical block tables per
//! request, capacity admission, and fragmentation metrics. It is what
//! lets the router answer "can I admit this request now?" without
//! touching XLA, and what a multi-engine deployment would shard over.

use anyhow::{bail, Result};
use std::collections::HashMap;

/// Configuration of one engine's KV memory.
#[derive(Clone, Debug)]
pub struct KvConfig {
    /// tokens per block (page size)
    pub block_size: usize,
    /// total physical blocks available
    pub n_blocks: usize,
    /// per-request hard cap (seq capacity of the executables)
    pub max_tokens_per_request: usize,
}

impl KvConfig {
    /// Sizing for a model config at a given batch: one slot's sequence
    /// capacity, paged into blocks.
    pub fn for_model(seq: usize, batch: usize, block_size: usize) -> Self {
        let blocks_per_slot = seq.div_ceil(block_size);
        KvConfig {
            block_size,
            n_blocks: blocks_per_slot * batch,
            max_tokens_per_request: seq,
        }
    }
}

/// Per-request allocation state.
#[derive(Clone, Debug)]
struct Lease {
    blocks: Vec<usize>,
    tokens: usize,
}

/// The block manager. Free list + per-request block tables.
pub struct KvBlockManager {
    cfg: KvConfig,
    free: Vec<usize>,
    leases: HashMap<u64, Lease>,
    /// high-water mark of simultaneously used blocks
    pub peak_used: usize,
}

impl KvBlockManager {
    pub fn new(cfg: KvConfig) -> Self {
        let free = (0..cfg.n_blocks).rev().collect();
        KvBlockManager { cfg, free, leases: HashMap::new(), peak_used: 0 }
    }

    pub fn used_blocks(&self) -> usize {
        self.cfg.n_blocks - self.free.len()
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Blocks currently held by live leases. The conservation invariant
    /// `free_blocks() + leased_blocks() == n_blocks()` must hold after
    /// EVERY operation — the churn tests pin it.
    pub fn leased_blocks(&self) -> usize {
        self.leases.values().map(|l| l.blocks.len()).sum()
    }

    pub fn n_blocks(&self) -> usize {
        self.cfg.n_blocks
    }

    fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.cfg.block_size)
    }

    /// Can a request with `prompt_len` tokens and up to `max_new` more
    /// be admitted right now (worst-case reservation policy)?
    pub fn can_admit(&self, prompt_len: usize, max_new: usize) -> bool {
        let total = (prompt_len + max_new).min(self.cfg.max_tokens_per_request);
        self.blocks_for(total) <= self.free.len()
    }

    /// Reserve blocks for a request's prompt (+ worst-case generation).
    pub fn admit(&mut self, req_id: u64, prompt_len: usize, max_new: usize) -> Result<()> {
        if self.leases.contains_key(&req_id) {
            bail!("request {req_id} already admitted");
        }
        let total = (prompt_len + max_new).min(self.cfg.max_tokens_per_request);
        let need = self.blocks_for(total);
        if need > self.free.len() {
            bail!(
                "admission rejected for {req_id}: need {need} blocks, {} free",
                self.free.len()
            );
        }
        let mut blocks = Vec::with_capacity(need);
        for _ in 0..need {
            match self.free.pop() {
                Some(b) => blocks.push(b),
                // unreachable given the `need <= free.len()` gate above,
                // but an accounting bug must error, not panic mid-serve
                None => bail!("KV free list exhausted mid-admission for {req_id}"),
            }
        }
        self.leases.insert(req_id, Lease { blocks, tokens: prompt_len });
        self.peak_used = self.peak_used.max(self.used_blocks());
        Ok(())
    }

    /// Record one generated token; errors if the lease would overflow.
    pub fn append_token(&mut self, req_id: u64) -> Result<()> {
        let cfg_cap = self.cfg.max_tokens_per_request;
        let lease = match self.leases.get_mut(&req_id) {
            Some(l) => l,
            None => bail!("no lease for request {req_id}"),
        };
        if lease.tokens + 1 > cfg_cap {
            bail!("request {req_id} exceeded seq capacity {cfg_cap}");
        }
        lease.tokens += 1;
        if lease.tokens > lease.blocks.len() * self.cfg.block_size {
            bail!("request {req_id} outgrew its reservation (bug)");
        }
        Ok(())
    }

    /// The logical → physical block table for a request (what a paged
    /// attention kernel would consume).
    pub fn block_table(&self, req_id: u64) -> Option<&[usize]> {
        self.leases.get(&req_id).map(|l| l.blocks.as_slice())
    }

    pub fn tokens_of(&self, req_id: u64) -> Option<usize> {
        self.leases.get(&req_id).map(|l| l.tokens)
    }

    /// Release a finished request's blocks back to the free list.
    pub fn release(&mut self, req_id: u64) -> Result<usize> {
        let lease = match self.leases.remove(&req_id) {
            Some(l) => l,
            None => bail!("no lease for request {req_id}"),
        };
        let n = lease.blocks.len();
        self.free.extend(lease.blocks);
        Ok(n)
    }

    /// Internal-fragmentation ratio: reserved-but-unused token slots /
    /// reserved slots (the waste the paper's fixed-batch engines accept).
    pub fn fragmentation(&self) -> f64 {
        let mut reserved = 0usize;
        let mut used = 0usize;
        for l in self.leases.values() {
            reserved += l.blocks.len() * self.cfg.block_size;
            used += l.tokens;
        }
        if reserved == 0 {
            0.0
        } else {
            1.0 - used as f64 / reserved as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;

    fn mgr(blocks: usize) -> KvBlockManager {
        KvBlockManager::new(KvConfig {
            block_size: 16,
            n_blocks: blocks,
            max_tokens_per_request: 96,
        })
    }

    #[test]
    fn admit_release_roundtrip() {
        let mut m = mgr(12);
        assert!(m.can_admit(20, 30)); // 50 tokens → 4 blocks
        m.admit(1, 20, 30).unwrap();
        assert_eq!(m.used_blocks(), 4);
        assert_eq!(m.block_table(1).unwrap().len(), 4);
        assert_eq!(m.release(1).unwrap(), 4);
        assert_eq!(m.used_blocks(), 0);
    }

    #[test]
    fn capacity_enforced() {
        let mut m = mgr(4);
        m.admit(1, 30, 30).unwrap(); // 60 tok → 4 blocks: all of them
        assert!(!m.can_admit(1, 1));
        assert!(m.admit(2, 1, 1).is_err());
        m.release(1).unwrap();
        assert!(m.can_admit(1, 1));
    }

    #[test]
    fn seq_cap_clamps_reservation() {
        let mut m = mgr(100);
        // prompt+max_new over the 96-token cap reserves only 96 → 6 blocks
        m.admit(1, 90, 50).unwrap();
        assert_eq!(m.block_table(1).unwrap().len(), 6);
    }

    #[test]
    fn append_respects_capacity() {
        let mut m = mgr(10);
        m.admit(1, 94, 2).unwrap();
        m.append_token(1).unwrap();
        m.append_token(1).unwrap();
        assert!(m.append_token(1).is_err()); // 97 > 96
    }

    #[test]
    fn double_admit_and_unknown_release_rejected() {
        let mut m = mgr(10);
        m.admit(1, 10, 10).unwrap();
        assert!(m.admit(1, 5, 5).is_err());
        assert!(m.release(99).is_err());
        assert!(m.append_token(98).is_err());
    }

    #[test]
    fn fragmentation_math() {
        let mut m = mgr(10);
        m.admit(1, 1, 31).unwrap(); // reserves 2 blocks = 32 slots, uses 1
        let f = m.fragmentation();
        assert!((f - 31.0 / 32.0).abs() < 1e-9, "{f}");
    }

    #[test]
    fn no_leaks_under_random_workload() {
        forall("kv manager leak-free", 40, |g| {
            let mut m = mgr(g.usize_in(4, 40));
            let mut live: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..g.usize_in(10, 120) {
                if g.bool() || live.is_empty() {
                    let p = g.usize_in(1, 40);
                    let n = g.usize_in(1, 40);
                    if m.can_admit(p, n) {
                        m.admit(next_id, p, n).unwrap();
                        live.push(next_id);
                        next_id += 1;
                    }
                } else {
                    let i = g.usize_in(0, live.len() - 1);
                    let id = live.swap_remove(i);
                    m.release(id).unwrap();
                }
            }
            for id in live.drain(..) {
                m.release(id).unwrap();
            }
            assert_eq!(m.used_blocks(), 0, "blocks leaked");
        });
    }

    #[test]
    fn churn_interleavings_conserve_blocks() {
        // randomized admit/append/release interleavings: the block pool
        // is conserved after EVERY operation (free + leased == total),
        // peak_used is monotone, and appends never corrupt accounting
        forall("kv manager churn invariants", 40, |g| {
            let mut m = mgr(g.usize_in(4, 40));
            let total = m.n_blocks();
            let mut live: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            let mut last_peak = 0usize;
            for _ in 0..g.usize_in(20, 150) {
                match g.usize_in(0, 2) {
                    0 => {
                        let p = g.usize_in(1, 40);
                        let n = g.usize_in(1, 40);
                        if m.can_admit(p, n) {
                            m.admit(next_id, p, n).unwrap();
                            live.push(next_id);
                            next_id += 1;
                        }
                    }
                    1 if !live.is_empty() => {
                        // appends may legitimately hit the lease cap;
                        // they must never break conservation either way
                        let id = live[g.usize_in(0, live.len() - 1)];
                        let _ = m.append_token(id);
                    }
                    _ if !live.is_empty() => {
                        let i = g.usize_in(0, live.len() - 1);
                        m.release(live.swap_remove(i)).unwrap();
                    }
                    _ => {}
                }
                assert_eq!(
                    m.free_blocks() + m.leased_blocks(),
                    total,
                    "block conservation violated"
                );
                assert!(m.peak_used >= last_peak, "peak_used went backwards");
                assert!(m.peak_used >= m.used_blocks());
                last_peak = m.peak_used;
            }
            for id in live.drain(..) {
                m.release(id).unwrap();
            }
            assert_eq!(m.free_blocks(), total, "blocks leaked");
            assert_eq!(m.leased_blocks(), 0);
        });
    }

    #[test]
    fn release_reopens_admission_mid_batch() {
        // continuous batching depends on this: releasing ONE lease makes
        // its blocks admissible immediately, while other leases stay live
        let mut m = mgr(4);
        m.admit(1, 20, 12).unwrap(); // 32 tok → 2 blocks
        m.admit(2, 20, 12).unwrap(); // 2 more — pool exhausted
        assert!(!m.can_admit(20, 12));
        m.release(1).unwrap();
        assert!(m.can_admit(20, 12), "freed blocks must be immediately re-admittable");
        m.admit(3, 20, 12).unwrap();
        assert_eq!(m.tokens_of(2), Some(20), "live lease untouched by the churn");
        assert_eq!(m.free_blocks() + m.leased_blocks(), m.n_blocks());
    }

    #[test]
    fn for_model_sizing() {
        let cfg = KvConfig::for_model(96, 4, 16);
        assert_eq!(cfg.n_blocks, 24);
        let m = KvBlockManager::new(cfg);
        assert_eq!(m.free_blocks(), 24);
    }
}
