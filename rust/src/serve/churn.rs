//! XLA-free continuous-batching churn harness.
//!
//! Drives the REAL admission machinery — [`plan_admissions`], the
//! [`KvBlockManager`] block accounting, and both KV state layouts
//! ([`SlotKv`] strided vs [`FullKv`] full-splice reference) — through a
//! synthetic arrival process with no executables involved: decode steps
//! are virtual (a step counter plus a fresh simulated KV image), so the
//! whole thing runs in CI without artifacts. This is what the
//! equivalence property test (`rust/tests/prop_kv_admission.rs`), the
//! churn throughput benches, and the `churn_admission` CI example build
//! on.
//!
//! In `KvMode::Both` the harness maintains BOTH layouts through every
//! admission and decode swap and bit-compares them after each mutation —
//! any divergence of the slot-strided path from the full-splice
//! reference fails immediately, attributed to the exact operation.

use super::engine::plan_admissions;
use super::kvcache::{KvBlockManager, KvConfig};
use super::kvstate::{FullKv, KvLayout, SlotKv};
use super::metrics::ServeMetrics;
use super::trace::{QueuedRequest, Request};
use crate::util::prng::Rng;
use anyhow::{ensure, Result};
use std::collections::VecDeque;

/// Which KV state layout(s) the harness maintains.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvMode {
    /// slot-strided only (the fast path; what the benches time)
    Strided,
    /// monolithic full-splice only (the "before" baseline)
    FullSplice,
    /// both, bit-compared after every mutation (the equivalence oracle)
    Both,
}

#[derive(Clone, Debug)]
pub struct ChurnConfig {
    pub layout: KvLayout,
    pub batch: usize,
    pub n_requests: usize,
    pub prompt_len: (usize, usize),
    /// fraction of requests drawing from `long_prompt_len` (mixed
    /// prompt lengths; may exceed `seq` to exercise clamping)
    pub long_frac: f64,
    pub long_prompt_len: (usize, usize),
    pub max_new: (usize, usize),
    /// mean inter-arrival gap in virtual decode steps (exponential)
    pub mean_gap_steps: f64,
    /// fraction of requests generated unservable (empty prompt) so
    /// rejection interleaves with admission
    pub reject_frac: f64,
    /// drain-between-batches baseline: only admit into an idle engine
    pub drain: bool,
    pub mode: KvMode,
    pub seed: u64,
}

impl Default for ChurnConfig {
    fn default() -> Self {
        ChurnConfig {
            layout: KvLayout { layers: 2, heads: 2, seq: 32, d_head: 4 },
            batch: 4,
            n_requests: 24,
            prompt_len: (4, 10),
            long_frac: 0.0,
            long_prompt_len: (16, 24),
            max_new: (4, 10),
            mean_gap_steps: 2.0,
            reject_frac: 0.0,
            drain: false,
            mode: KvMode::Both,
            seed: 0xC0FFEE,
        }
    }
}

/// What one churn run did, in virtual-step time.
#[derive(Clone, Debug, Default)]
pub struct ChurnReport {
    pub completions: u64,
    pub total_generated: u64,
    /// virtual decode steps executed
    pub steps: u64,
    /// merged-prefill calls (one per admission round)
    pub prefills: u64,
    pub rejected: u64,
    pub dropped: u64,
    /// requests admitted while other slots were still decoding — the
    /// continuous-batching signature; always 0 under `drain`
    pub mid_batch_admissions: u64,
    pub queue_peak: usize,
    pub admit_bytes_strided: u64,
    pub admit_bytes_fullsplice: u64,
    /// blocks not back on the free list at the end (must be 0)
    pub blocks_leaked: usize,
    /// `(request id, virtual step)` at admission
    pub admission_steps: Vec<(u64, u64)>,
    /// `(request id, virtual step)` at completion
    pub completion_steps: Vec<(u64, u64)>,
}

/// One live slot in the virtual engine (mirrors `Slot::Active`).
struct Active {
    id: u64,
    max_new: usize,
    pos: usize,
    generated: usize,
}

/// Deterministic arrival process: `(arrival step, request)` pairs,
/// exponential gaps, mixed short/long prompts, a `reject_frac` share of
/// unservable (empty-prompt) requests.
pub fn churn_arrivals(cfg: &ChurnConfig) -> Vec<(u64, Request)> {
    let mut rng = Rng::from_stream(cfg.seed, "churn");
    let mut arrival = 0u64;
    (0..cfg.n_requests)
        .map(|i| {
            let reject = cfg.reject_frac > 0.0 && rng.coin(cfg.reject_frac);
            let (lo, hi) = if cfg.long_frac > 0.0 && rng.coin(cfg.long_frac) {
                cfg.long_prompt_len
            } else {
                cfg.prompt_len
            };
            let plen = if reject { 0 } else { lo + rng.below(hi - lo + 1) };
            let max_new = cfg.max_new.0 + rng.below(cfg.max_new.1 - cfg.max_new.0 + 1);
            let prompt: Vec<i32> = (0..plen).map(|t| ((i * 31 + t * 7) % 97) as i32).collect();
            if cfg.mean_gap_steps > 0.0 {
                let u = rng.uniform().max(1e-9);
                arrival += (-(u.ln()) * cfg.mean_gap_steps) as u64;
            }
            (arrival, Request { id: i as u64, prompt, max_new, arrival_ms: arrival })
        })
        .collect()
}

/// Bit-compare the two layouts' monolithic images (KvMode::Both only).
fn verify_equal(strided: &Option<SlotKv>, full: &Option<FullKv>) -> Result<()> {
    let (Some(s), Some(f)) = (strided, full) else { return Ok(()) };
    let (sk, sv) = s.to_full()?;
    let (fk, fv) = f.to_full()?;
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
    ensure!(
        bits(&sk) == bits(&fk) && bits(&sv) == bits(&fv),
        "slot-strided KV diverged from the full-splice reference"
    );
    Ok(())
}

pub fn run_churn(cfg: &ChurnConfig) -> Result<ChurnReport> {
    run_churn_with(cfg, churn_arrivals(cfg))
}

/// Run the harness over an explicit arrival sequence (sorted by step).
pub fn run_churn_with(cfg: &ChurnConfig, arrivals: Vec<(u64, Request)>) -> Result<ChurnReport> {
    let layout = cfg.layout;
    let batch = cfg.batch;
    let seq = layout.seq;
    let mut kv_mgr = KvBlockManager::new(KvConfig::for_model(seq, batch, 16));
    let mut metrics = ServeMetrics::default();
    let mut strided = match cfg.mode {
        KvMode::FullSplice => None,
        _ => Some(SlotKv::new(layout, batch)?),
    };
    let mut full = match cfg.mode {
        KvMode::Strided => None,
        _ => Some(FullKv::new(layout, batch)?),
    };
    // the simulated prefill/decode KV images (contents are arbitrary —
    // only bit-equivalence between the two layouts matters)
    let mut fill = Rng::from_stream(cfg.seed, "churn-kv");
    let mut slots: Vec<Option<Active>> = (0..batch).map(|_| None).collect();
    let mut arrivals: VecDeque<(u64, Request)> = arrivals.into();
    let mut queue: VecDeque<QueuedRequest> = VecDeque::new();
    let mut report = ChurnReport::default();
    let mut step = 0u64;
    loop {
        while arrivals.front().map(|(t, _)| *t <= step).unwrap_or(false) {
            if let Some((_, r)) = arrivals.pop_front() {
                // virtual-step timestamps: the harness has no wall clock
                queue.push_back(QueuedRequest::at(r, step as f64));
            }
        }
        let active = slots.iter().filter(|s| s.is_some()).count();
        if arrivals.is_empty() && queue.is_empty() && active == 0 {
            break;
        }
        report.queue_peak = report.queue_peak.max(queue.len());
        // continuous batching admits on ANY step; the drain baseline
        // only into an idle engine
        if (!cfg.drain || active == 0) && !queue.is_empty() {
            let idle: Vec<usize> = slots
                .iter()
                .enumerate()
                .filter(|(_, s)| s.is_none())
                .map(|(b, _)| b)
                .collect();
            let planned = plan_admissions(&mut queue, &mut kv_mgr, &idle, seq, &mut metrics)?;
            if !planned.is_empty() {
                // one merged prefill produces a fresh full-shape image;
                // each layout admits ONLY the planned slots from it
                let kc = fill.normal_vec(layout.full_elems(batch));
                let vc = fill.normal_vec(layout.full_elems(batch));
                let slot_ids: Vec<usize> = planned.iter().map(|(b, _, _)| *b).collect();
                if let Some(s) = strided.as_mut() {
                    s.admit_from_full(&slot_ids, &kc, &vc)?;
                }
                if let Some(f) = full.as_mut() {
                    f.admit_reference(&slot_ids, &kc, &vc)?;
                }
                report.prefills += 1;
                if active > 0 {
                    report.mid_batch_admissions += planned.len() as u64;
                }
                for (b, plen, qr) in planned {
                    report.admission_steps.push((qr.req.id, step));
                    // mirrors the engine: prefill samples one token
                    slots[b] = Some(Active {
                        id: qr.req.id,
                        max_new: qr.req.max_new,
                        pos: plen,
                        generated: 1,
                    });
                }
                verify_equal(&strided, &full)?;
            }
        }
        let active = slots.iter().filter(|s| s.is_some()).count();
        if active > 0 {
            // one virtual decode step: every layout swaps in the step's
            // per-slot outputs wholesale, exactly like the engine
            step += 1;
            report.steps += 1;
            let kc = fill.normal_vec(layout.full_elems(batch));
            let vc = fill.normal_vec(layout.full_elems(batch));
            if let Some(s) = strided.as_mut() {
                s.swap_from_full(&kc, &vc)?;
            }
            if let Some(f) = full.as_mut() {
                f.swap_host(&kc, &vc)?;
            }
            verify_equal(&strided, &full)?;
            for slot in slots.iter_mut() {
                let Some(a) = slot.as_mut() else { continue };
                a.pos += 1;
                a.generated += 1;
                kv_mgr.append_token(a.id)?;
                let capacity_hit = a.pos + 1 >= seq;
                if a.generated >= a.max_new || capacity_hit {
                    let (id, generated) = (a.id, a.generated as u64);
                    report.completion_steps.push((id, step));
                    report.total_generated += generated;
                    report.completions += 1;
                    kv_mgr.release(id)?;
                    *slot = None;
                }
            }
        } else {
            match arrivals.front() {
                // idle engine: jump straight to the next arrival
                Some((t, _)) => step = (*t).max(step + 1),
                None => {
                    // nothing running, nothing coming, head can never
                    // fit: surface the remainder instead of spinning
                    report.dropped += queue.len() as u64;
                    queue.clear();
                }
            }
        }
    }
    report.rejected = metrics.rejected;
    if let Some(s) = &strided {
        report.admit_bytes_strided = s.admit_bytes;
    }
    if let Some(f) = &full {
        report.admit_bytes_fullsplice = f.admit_bytes;
    }
    report.blocks_leaked = kv_mgr.n_blocks() - kv_mgr.free_blocks();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(id: u64, plen: usize, max_new: usize) -> Request {
        Request { id, prompt: vec![1i32; plen], max_new, arrival_ms: 0 }
    }

    fn step_of(steps: &[(u64, u64)], id: u64) -> u64 {
        steps.iter().find(|(i, _)| *i == id).map(|(_, s)| *s).unwrap()
    }

    #[test]
    fn continuous_batching_admits_mid_batch_and_drain_does_not() {
        // batch 2, three requests arriving together: req 0 (short) and
        // req 1 (long) fill the batch; req 2 waits. Under continuous
        // batching req 2 must take req 0's slot as soon as it frees,
        // BEFORE req 1 finishes; under drain it must wait for req 1.
        let cfg = ChurnConfig {
            layout: KvLayout { layers: 1, heads: 1, seq: 32, d_head: 2 },
            batch: 2,
            ..Default::default()
        };
        let arrivals = || vec![(0u64, mk(0, 4, 2)), (0, mk(1, 4, 8)), (0, mk(2, 4, 2))];
        let cont = run_churn_with(&cfg, arrivals()).unwrap();
        assert_eq!(cont.completions, 3);
        assert!(cont.mid_batch_admissions >= 1, "no mid-batch admission happened");
        assert!(cont.queue_peak >= 1, "req 2 never queued");
        let done0 = step_of(&cont.completion_steps, 0);
        let done1 = step_of(&cont.completion_steps, 1);
        let admit2 = step_of(&cont.admission_steps, 2);
        assert!(
            admit2 >= done0 && admit2 < done1,
            "req 2 must be admitted after req 0 completes ({done0}) but before \
             req 1 does ({done1}); got step {admit2}"
        );
        assert_eq!(cont.blocks_leaked, 0);
        // drain baseline: same workload, no mid-batch admission, and
        // strictly more decode steps for the same tokens
        let drain = run_churn_with(
            &ChurnConfig { drain: true, ..cfg.clone() },
            arrivals(),
        )
        .unwrap();
        assert_eq!(drain.completions, 3);
        assert_eq!(drain.mid_batch_admissions, 0);
        assert_eq!(drain.total_generated, cont.total_generated);
        assert!(
            drain.steps > cont.steps,
            "drain ({}) should need more steps than continuous ({})",
            drain.steps,
            cont.steps
        );
    }

    #[test]
    fn rejects_surface_in_accounting() {
        let cfg = ChurnConfig { reject_frac: 0.5, seed: 42, ..Default::default() };
        let r = run_churn(&cfg).unwrap();
        assert!(r.rejected > 0, "reject_frac 0.5 produced no rejections");
        assert!(r.completions > 0);
        assert_eq!(
            r.completions + r.rejected + r.dropped,
            cfg.n_requests as u64,
            "every request must be completed, rejected, or dropped"
        );
        assert_eq!(r.blocks_leaked, 0);
    }

    #[test]
    fn admission_byte_accounting_is_exact() {
        // strided: each admitted request moves exactly one slot's K+V
        // bytes, once. full-splice: every prefill round-trips the WHOLE
        // cache (4 × full image × 4 bytes).
        let cfg = ChurnConfig::default();
        let r = run_churn(&cfg).unwrap();
        assert_eq!(r.completions, cfg.n_requests as u64);
        assert_eq!(r.admit_bytes_strided, r.completions * cfg.layout.slot_kv_bytes());
        assert_eq!(
            r.admit_bytes_fullsplice,
            r.prefills * 4 * cfg.layout.full_elems(cfg.batch) as u64 * 4
        );
        assert!(
            r.admit_bytes_strided < r.admit_bytes_fullsplice,
            "strided admission moved MORE bytes than the full splice"
        );
    }

    #[test]
    fn burst_arrivals_report_backpressure() {
        let cfg = ChurnConfig { mean_gap_steps: 0.0, ..Default::default() };
        let r = run_churn(&cfg).unwrap();
        assert!(
            r.queue_peak > cfg.batch,
            "24 simultaneous arrivals into batch 4 must pile up a queue \
             (peak {})",
            r.queue_peak
        );
        assert_eq!(r.completions, cfg.n_requests as u64);
        assert_eq!(r.blocks_leaked, 0);
    }
}
