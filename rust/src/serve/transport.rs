//! Shard-to-shard activation transport for pipeline-parallel execution
//! (PERF.md §12): `[rows, cols]` hidden-state frames plus slot /
//! step / micro-batch headers, length-prefixed little-endian with an
//! FNV-1a trailer — the same integrity idiom as the artifact format, so
//! a flipped byte anywhere in a frame is caught at `recv`, never
//! decoded into garbage activations.
//!
//! Three implementations of [`ShardTransport`]:
//!   * [`LocalPipe`] — in-process, channel-backed, deterministic and
//!     XLA-free. Frames still round-trip through the WIRE BYTES (not
//!     moved as structs), so byte accounting and corruption handling
//!     are exercised even in tests and virtual-clock replays.
//!   * [`SocketTransport`] — a Unix-domain stream socket for real
//!     multi-process runs (`higgs serve-pipeline --socket`), either an
//!     anonymous `pair()` or a filesystem rendezvous derived from the
//!     `HIGGS_SHARD_SOCKET` path prefix.
//!   * [`TcpTransport`] — the same frame contract over `TcpStream`
//!     (`higgs serve-pipeline --tcp`), so ring links can leave the
//!     host; rendezvous addresses derive from the `HIGGS_SHARD_TCP`
//!     `host:base_port` knob (link i listens on `base_port + i`).
//!
//! This module is under the `wall-clock` audit rule: no `Instant`,
//! `SystemTime`, or sleeps — blocking reads are the only waiting
//! primitive, which keeps LocalPipe replays bit-deterministic.

use anyhow::{anyhow, bail, ensure, Result};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{rank, AuditMutex};
use std::sync::mpsc;

/// Frame kinds on the wire. A worker forwards [`FRAME_SHUTDOWN`] to its
/// downstream neighbour and exits, so one shutdown frame drains the
/// whole ring.
pub const FRAME_DECODE: u8 = 0;
pub const FRAME_PREFILL: u8 = 1;
pub const FRAME_SHUTDOWN: u8 = 2;

/// Fixed-size part of the payload: kind(1) + mb(4) + step(8) + rows(4)
/// + cols(4) + active(8).
const HEADER_BYTES: usize = 29;
/// Wire overhead around the payload: u32 length prefix + u64 FNV
/// trailer.
pub const WIRE_OVERHEAD: usize = 12;
/// Upper bound on an accepted payload (64 MiB) — a corrupt length
/// prefix must produce an error, not an OOM-sized allocation.
const MAX_PAYLOAD: usize = 64 << 20;

/// One hop's worth of activations: `rows × cols` f32 hidden states plus
/// the per-row KV write positions and a live-slot bitmap.
///
/// * decode frames: `mb` is the micro-batch index, `step` the decode
///   round; row r belongs to slot `mb * rows + r`, live iff bit r of
///   `active` is set, writing KV at `pos[r]`.
/// * prefill frames: `mb` is the SLOT being admitted, `rows` the
///   clamped prompt length; row t is prompt position t (`pos[t] == t`).
#[derive(Clone, Debug, PartialEq)]
pub struct ActivationFrame {
    pub kind: u8,
    pub mb: u32,
    pub step: u64,
    pub rows: u32,
    pub cols: u32,
    /// bitmap of live rows (decode frames; 0 elsewhere)
    pub active: u64,
    /// per-row KV write position, `rows` entries
    pub pos: Vec<u32>,
    /// row-major `[rows, cols]` hidden states
    pub data: Vec<f32>,
}

impl ActivationFrame {
    pub fn shutdown() -> ActivationFrame {
        ActivationFrame {
            kind: FRAME_SHUTDOWN,
            mb: 0,
            step: 0,
            rows: 0,
            cols: 0,
            active: 0,
            pos: Vec::new(),
            data: Vec::new(),
        }
    }

    fn payload_len(&self) -> usize {
        HEADER_BYTES + self.pos.len() * 4 + self.data.len() * 4
    }

    /// Total bytes this frame occupies on the wire.
    pub fn wire_len(&self) -> usize {
        self.payload_len() + WIRE_OVERHEAD
    }

    /// Serialize to the full wire form: `len:u32 LE` over the payload,
    /// the payload, then `fnv1a(payload):u64 LE`.
    pub fn to_bytes(&self) -> Vec<u8> {
        let plen = self.payload_len();
        let mut out = Vec::with_capacity(plen + WIRE_OVERHEAD);
        out.extend_from_slice(&(plen as u32).to_le_bytes());
        out.push(self.kind);
        out.extend_from_slice(&self.mb.to_le_bytes());
        out.extend_from_slice(&self.step.to_le_bytes());
        out.extend_from_slice(&self.rows.to_le_bytes());
        out.extend_from_slice(&self.cols.to_le_bytes());
        out.extend_from_slice(&self.active.to_le_bytes());
        for p in &self.pos {
            out.extend_from_slice(&p.to_le_bytes());
        }
        for v in &self.data {
            out.extend_from_slice(&v.to_le_bytes());
        }
        let fnv = crate::util::fnv1a(out.iter().skip(4).copied());
        out.extend_from_slice(&fnv.to_le_bytes());
        out
    }

    /// Parse a full wire frame (length prefix + payload + FNV trailer).
    /// Every failure mode — truncation, trailing garbage, a checksum
    /// mismatch, inconsistent header counts — is an `Err`, never a
    /// panic: a corrupt frame must not tear down the engine.
    pub fn from_bytes(buf: &[u8]) -> Result<ActivationFrame> {
        let (len_b, rest) = take(buf, 4).map_err(|_| anyhow!("frame shorter than its length prefix"))?;
        let plen = u32::from_le_bytes(arr4(len_b)?) as usize;
        ensure!(plen <= MAX_PAYLOAD, "frame payload length {plen} exceeds the {MAX_PAYLOAD} cap");
        ensure!(
            rest.len() == plen + 8,
            "frame length prefix says {plen} payload bytes, got {} (+8 trailer expected)",
            rest.len().saturating_sub(8)
        );
        let (payload, trailer) = take(rest, plen)?;
        let fnv_want = u64::from_le_bytes(arr8(trailer)?);
        let fnv_got = crate::util::fnv1a(payload.iter().copied());
        ensure!(
            fnv_got == fnv_want,
            "frame checksum mismatch: computed {fnv_got:#018x}, trailer {fnv_want:#018x}"
        );
        Self::from_payload(payload)
    }

    fn from_payload(payload: &[u8]) -> Result<ActivationFrame> {
        let (kind_b, p) = take(payload, 1)?;
        let kind = kind_b.first().copied().ok_or_else(|| anyhow!("empty frame header"))?;
        ensure!(kind <= FRAME_SHUTDOWN, "unknown frame kind {kind}");
        let (mb_b, p) = take(p, 4)?;
        let (step_b, p) = take(p, 8)?;
        let (rows_b, p) = take(p, 4)?;
        let (cols_b, p) = take(p, 4)?;
        let (active_b, p) = take(p, 8)?;
        let rows = u32::from_le_bytes(arr4(rows_b)?) as usize;
        let cols = u32::from_le_bytes(arr4(cols_b)?) as usize;
        let want = rows
            .checked_mul(4)
            .and_then(|pb| rows.checked_mul(cols).and_then(|n| n.checked_mul(4)).map(|db| (pb, db)));
        let Some((pos_bytes, data_bytes)) = want else {
            bail!("frame header rows/cols overflow: rows={rows} cols={cols}")
        };
        ensure!(
            p.len() == pos_bytes + data_bytes,
            "frame body {} bytes, header wants {} (rows={rows} cols={cols})",
            p.len(),
            pos_bytes + data_bytes
        );
        let (pos_b, data_b) = take(p, pos_bytes)?;
        let mut pos = Vec::with_capacity(rows);
        for c in pos_b.chunks_exact(4) {
            pos.push(u32::from_le_bytes(arr4(c)?));
        }
        let mut data = Vec::with_capacity(rows * cols);
        for c in data_b.chunks_exact(4) {
            data.push(f32::from_le_bytes(arr4(c)?));
        }
        Ok(ActivationFrame {
            kind,
            mb: u32::from_le_bytes(arr4(mb_b)?),
            step: u64::from_le_bytes(arr8(step_b)?),
            rows: rows as u32,
            cols: cols as u32,
            active: u64::from_le_bytes(arr8(active_b)?),
            pos,
            data,
        })
    }
}

fn take(buf: &[u8], n: usize) -> Result<(&[u8], &[u8])> {
    ensure!(buf.len() >= n, "frame truncated: wanted {n} bytes, have {}", buf.len());
    Ok(buf.split_at(n))
}

fn arr4(b: &[u8]) -> Result<[u8; 4]> {
    b.try_into().map_err(|_| anyhow!("frame field: expected 4 bytes, got {}", b.len()))
}

fn arr8(b: &[u8]) -> Result<[u8; 8]> {
    b.try_into().map_err(|_| anyhow!("frame field: expected 8 bytes, got {}", b.len()))
}

/// One directed link between pipeline stages. `send`/`recv` take
/// `&self` (counters are atomic, stream state is behind a mutex) so a
/// transport end can sit in a `Box<dyn ShardTransport + Send>` shared
/// with the owning stage's loop.
pub trait ShardTransport {
    fn send(&self, frame: &ActivationFrame) -> Result<()>;
    /// Block until the next frame arrives, verify its checksum, and
    /// decode it. A closed peer or a corrupt frame is an `Err`.
    fn recv(&self) -> Result<ActivationFrame>;
    /// Push raw bytes as-is (no framing added) — the corruption seam
    /// for tests: inject a flipped byte or a truncated frame and watch
    /// the receiver error instead of panicking.
    fn send_raw(&self, bytes: Vec<u8>) -> Result<()>;
    fn frames_sent(&self) -> u64;
    fn bytes_sent(&self) -> u64;
}

/// In-process transport end over an `mpsc` byte channel. Each `pair()`
/// gives the two ends of a duplex link; a ring of stages holds one end
/// of its upstream link (recv side) and one of its downstream link
/// (send side).
pub struct LocalPipe {
    tx: mpsc::Sender<Vec<u8>>,
    rx: AuditMutex<mpsc::Receiver<Vec<u8>>>,
    frames: AtomicU64,
    bytes: AtomicU64,
}

impl LocalPipe {
    /// A connected duplex pair: what `a` sends, `b` receives, and vice
    /// versa.
    pub fn pair() -> (LocalPipe, LocalPipe) {
        let (atx, brx) = mpsc::channel::<Vec<u8>>();
        let (btx, arx) = mpsc::channel::<Vec<u8>>();
        let mk = |tx: mpsc::Sender<Vec<u8>>, rx: mpsc::Receiver<Vec<u8>>| LocalPipe {
            tx,
            rx: AuditMutex::new("transport.pipe.rx", rank::TRANSPORT_PIPE, rx),
            frames: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
        };
        (mk(atx, arx), mk(btx, brx))
    }
}

impl ShardTransport for LocalPipe {
    fn send(&self, frame: &ActivationFrame) -> Result<()> {
        let wire = frame.to_bytes();
        self.bytes.fetch_add(wire.len() as u64, Ordering::Relaxed);
        self.frames.fetch_add(1, Ordering::Relaxed);
        self.tx.send(wire).map_err(|_| anyhow!("local pipe closed: peer stage is gone"))
    }

    fn recv(&self) -> Result<ActivationFrame> {
        // The mutex exists only to make `mpsc::Receiver` Sync; holding
        // it across the blocking recv is the one sanctioned
        // blocking-under-lock site (grandfathered in the allowlist).
        let rx = self.rx.lock();
        let wire = rx.recv().map_err(|_| anyhow!("local pipe closed: peer stage is gone"))?;
        ActivationFrame::from_bytes(&wire)
    }

    fn send_raw(&self, bytes: Vec<u8>) -> Result<()> {
        self.bytes.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        self.frames.fetch_add(1, Ordering::Relaxed);
        self.tx.send(bytes).map_err(|_| anyhow!("local pipe closed: peer stage is gone"))
    }

    fn frames_sent(&self) -> u64 {
        self.frames.load(Ordering::Relaxed)
    }

    fn bytes_sent(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }
}

/// Unix-domain stream transport end for multi-process pipelines. The
/// wire format is identical to [`LocalPipe`]'s — a frame serialized by
/// one is parseable by the other.
pub struct SocketTransport {
    stream: AuditMutex<UnixStream>,
    frames: AtomicU64,
    bytes: AtomicU64,
}

impl SocketTransport {
    fn wrap(stream: UnixStream) -> SocketTransport {
        SocketTransport {
            stream: AuditMutex::new("transport.socket.stream", rank::TRANSPORT_STREAM, stream),
            frames: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
        }
    }

    /// Anonymous connected pair (single-host multi-thread or
    /// fork-style multi-process runs).
    pub fn pair() -> Result<(SocketTransport, SocketTransport)> {
        let (a, b) = UnixStream::pair().map_err(|e| anyhow!("socketpair: {e}"))?;
        Ok((Self::wrap(a), Self::wrap(b)))
    }

    /// Bind `path` and accept one peer (the upstream stage listens).
    pub fn listen(path: &std::path::Path) -> Result<SocketTransport> {
        // a stale socket file from a previous run would fail the bind
        let _ = std::fs::remove_file(path);
        let listener = UnixListener::bind(path)
            .map_err(|e| anyhow!("bind {}: {e}", path.display()))?;
        let (stream, _) = listener.accept().map_err(|e| anyhow!("accept on {}: {e}", path.display()))?;
        Ok(Self::wrap(stream))
    }

    /// Connect to a listening peer (the downstream stage connects).
    pub fn connect(path: &std::path::Path) -> Result<SocketTransport> {
        let stream = UnixStream::connect(path)
            .map_err(|e| anyhow!("connect {}: {e}", path.display()))?;
        Ok(Self::wrap(stream))
    }

    /// Filesystem rendezvous path for ring link `link` (coordinator →
    /// shard 0 is link 0), derived from the `HIGGS_SHARD_SOCKET` path
    /// prefix. `None` when the knob is unset — callers fall back to
    /// anonymous `pair()`s.
    pub fn rendezvous_path(link: usize) -> Option<PathBuf> {
        crate::util::env_str("HIGGS_SHARD_SOCKET").map(|p| PathBuf::from(format!("{p}.{link}")))
    }
}

impl ShardTransport for SocketTransport {
    fn send(&self, frame: &ActivationFrame) -> Result<()> {
        self.send_raw(frame.to_bytes())
    }

    fn recv(&self) -> Result<ActivationFrame> {
        let mut stream = self.stream.lock();
        let mut len_b = [0u8; 4];
        stream.read_exact(&mut len_b).map_err(|e| anyhow!("socket read (length): {e}"))?;
        let plen = u32::from_le_bytes(len_b) as usize;
        ensure!(plen <= MAX_PAYLOAD, "frame payload length {plen} exceeds the {MAX_PAYLOAD} cap");
        let mut rest = vec![0u8; plen + 8];
        stream.read_exact(&mut rest).map_err(|e| anyhow!("socket read (payload): {e}"))?;
        let mut wire = Vec::with_capacity(4 + rest.len());
        wire.extend_from_slice(&len_b);
        wire.extend_from_slice(&rest);
        ActivationFrame::from_bytes(&wire)
    }

    fn send_raw(&self, bytes: Vec<u8>) -> Result<()> {
        let mut stream = self.stream.lock();
        stream.write_all(&bytes).map_err(|e| anyhow!("socket write: {e}"))?;
        self.bytes.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        self.frames.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    fn frames_sent(&self) -> u64 {
        self.frames.load(Ordering::Relaxed)
    }

    fn bytes_sent(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }
}

/// TCP stream transport end for multi-host pipelines (ROADMAP item 1's
/// remaining gap). The wire format is identical to [`LocalPipe`]'s and
/// [`SocketTransport`]'s — a frame serialized by one is parseable by
/// the others — so shard workers can be placed by address without any
/// change to the coordinator.
pub struct TcpTransport {
    stream: AuditMutex<TcpStream>,
    frames: AtomicU64,
    bytes: AtomicU64,
}

impl TcpTransport {
    fn wrap(stream: TcpStream) -> TcpTransport {
        // activation frames are latency-critical hops, not bulk bytes
        let _ = stream.set_nodelay(true);
        TcpTransport {
            stream: AuditMutex::new("transport.tcp.stream", rank::TRANSPORT_STREAM, stream),
            frames: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
        }
    }

    /// Connected loopback pair (single-host runs and tests): bind an
    /// ephemeral port, connect to it, accept the one peer.
    pub fn pair() -> Result<(TcpTransport, TcpTransport)> {
        let listener =
            TcpListener::bind(("127.0.0.1", 0)).map_err(|e| anyhow!("tcp bind: {e}"))?;
        let addr = listener.local_addr().map_err(|e| anyhow!("tcp local_addr: {e}"))?;
        let a = TcpStream::connect(addr).map_err(|e| anyhow!("tcp connect {addr}: {e}"))?;
        let (b, _) = listener.accept().map_err(|e| anyhow!("tcp accept: {e}"))?;
        Ok((Self::wrap(a), Self::wrap(b)))
    }

    /// Bind `addr` and accept one peer (the upstream stage listens).
    pub fn listen(addr: &str) -> Result<TcpTransport> {
        let listener = TcpListener::bind(addr).map_err(|e| anyhow!("tcp bind {addr}: {e}"))?;
        let (stream, _) = listener.accept().map_err(|e| anyhow!("tcp accept on {addr}: {e}"))?;
        Ok(Self::wrap(stream))
    }

    /// Connect to a listening peer (the downstream stage connects).
    pub fn connect(addr: &str) -> Result<TcpTransport> {
        let stream = TcpStream::connect(addr).map_err(|e| anyhow!("tcp connect {addr}: {e}"))?;
        Ok(Self::wrap(stream))
    }

    /// Rendezvous address for ring link `link` (coordinator → shard 0
    /// is link 0), derived from the `HIGGS_SHARD_TCP` knob:
    /// `host:base_port` means link i uses `host:(base_port + i)`.
    /// `Ok(None)` when the knob is unset — callers fall back to
    /// loopback `pair()`s; a malformed value is an `Err`, not a
    /// silent fallback.
    pub fn rendezvous_addr(link: usize) -> Result<Option<String>> {
        let Some(spec) = crate::util::env_str("HIGGS_SHARD_TCP") else {
            return Ok(None);
        };
        let (host, base) = spec
            .rsplit_once(':')
            .ok_or_else(|| anyhow!("HIGGS_SHARD_TCP must be host:base_port, got {spec:?}"))?;
        let base: u16 = base
            .parse()
            .map_err(|_| anyhow!("HIGGS_SHARD_TCP base port {base:?} is not a u16"))?;
        let link16 = u16::try_from(link).map_err(|_| anyhow!("ring link {link} out of range"))?;
        let port = base
            .checked_add(link16)
            .ok_or_else(|| anyhow!("HIGGS_SHARD_TCP port {base}+{link} overflows u16"))?;
        Ok(Some(format!("{host}:{port}")))
    }
}

impl ShardTransport for TcpTransport {
    fn send(&self, frame: &ActivationFrame) -> Result<()> {
        self.send_raw(frame.to_bytes())
    }

    fn recv(&self) -> Result<ActivationFrame> {
        let mut stream = self.stream.lock();
        let mut len_b = [0u8; 4];
        stream.read_exact(&mut len_b).map_err(|e| anyhow!("tcp read (length): {e}"))?;
        let plen = u32::from_le_bytes(len_b) as usize;
        ensure!(plen <= MAX_PAYLOAD, "frame payload length {plen} exceeds the {MAX_PAYLOAD} cap");
        let mut rest = vec![0u8; plen + 8];
        stream.read_exact(&mut rest).map_err(|e| anyhow!("tcp read (payload): {e}"))?;
        let mut wire = Vec::with_capacity(4 + rest.len());
        wire.extend_from_slice(&len_b);
        wire.extend_from_slice(&rest);
        ActivationFrame::from_bytes(&wire)
    }

    fn send_raw(&self, bytes: Vec<u8>) -> Result<()> {
        let mut stream = self.stream.lock();
        stream.write_all(&bytes).map_err(|e| anyhow!("tcp write: {e}"))?;
        self.bytes.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        self.frames.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    fn frames_sent(&self) -> u64 {
        self.frames.load(Ordering::Relaxed)
    }

    fn bytes_sent(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame() -> ActivationFrame {
        ActivationFrame {
            kind: FRAME_DECODE,
            mb: 3,
            step: 41,
            rows: 2,
            cols: 4,
            active: 0b10,
            pos: vec![7, 9],
            data: vec![1.0, -2.5, 0.0, -0.0, 3.5e-9, f32::MAX, 1e-40, 42.0],
        }
    }

    #[test]
    fn wire_roundtrip_bit_exact() {
        let f = frame();
        let wire = f.to_bytes();
        assert_eq!(wire.len(), f.wire_len());
        let g = ActivationFrame::from_bytes(&wire).unwrap();
        // PartialEq on f32 would conflate 0.0 and -0.0 — compare bits
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&g.data), bits(&f.data));
        assert_eq!((g.kind, g.mb, g.step, g.rows, g.cols, g.active, g.pos.clone()),
                   (f.kind, f.mb, f.step, f.rows, f.cols, f.active, f.pos.clone()));
    }

    #[test]
    fn corruption_and_truncation_error_not_panic() {
        let wire = frame().to_bytes();
        // every single-byte flip is caught (length prefix, header,
        // data, or trailer — FNV covers the payload, length/shape
        // checks cover the rest)
        for i in 0..wire.len() {
            let mut bad = wire.clone();
            bad[i] ^= 0x40;
            assert!(ActivationFrame::from_bytes(&bad).is_err(), "flip at byte {i} accepted");
        }
        // every truncation errors
        for n in 0..wire.len() {
            assert!(ActivationFrame::from_bytes(&wire[..n]).is_err(), "truncation to {n} accepted");
        }
        // trailing garbage errors
        let mut long = wire.clone();
        long.push(0);
        assert!(ActivationFrame::from_bytes(&long).is_err());
        // absurd length prefix errors without allocating
        let mut huge = wire;
        huge[..4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(ActivationFrame::from_bytes(&huge).is_err());
    }

    #[test]
    fn bad_header_fields_rejected() {
        let mut f = frame();
        f.kind = 9;
        let wire = f.to_bytes();
        assert!(ActivationFrame::from_bytes(&wire).is_err(), "unknown kind accepted");
        // rows/cols inconsistent with the body length
        let f = frame();
        let mut wire = f.to_bytes();
        // rows lives at payload offset 13 → wire offset 17
        wire[17] = 200;
        // re-seal the checksum so ONLY the shape check can catch it
        let plen = f.to_bytes().len() - WIRE_OVERHEAD;
        let fnv = crate::util::fnv1a(wire[4..4 + plen].iter().copied());
        let at = 4 + plen;
        wire[at..at + 8].copy_from_slice(&fnv.to_le_bytes());
        assert!(ActivationFrame::from_bytes(&wire).is_err(), "shape drift accepted");
    }

    #[test]
    fn local_pipe_duplex_and_counters() {
        let (a, b) = LocalPipe::pair();
        let f = frame();
        a.send(&f).unwrap();
        a.send(&ActivationFrame::shutdown()).unwrap();
        let g = b.recv().unwrap();
        assert_eq!(g.step, f.step);
        assert_eq!(b.recv().unwrap().kind, FRAME_SHUTDOWN);
        // reverse direction
        b.send(&f).unwrap();
        assert_eq!(a.recv().unwrap().mb, f.mb);
        assert_eq!(a.frames_sent(), 2);
        assert_eq!(a.bytes_sent(), (f.wire_len() + ActivationFrame::shutdown().wire_len()) as u64);
        assert_eq!(b.frames_sent(), 1);
    }

    #[test]
    fn local_pipe_raw_injection_surfaces_as_recv_error() {
        let (a, b) = LocalPipe::pair();
        let mut bad = frame().to_bytes();
        bad[8] ^= 1;
        a.send_raw(bad).unwrap();
        assert!(b.recv().is_err());
        // closed peer is an error, not a hang or panic
        drop(a);
        assert!(b.recv().is_err());
    }

    #[test]
    fn socket_transport_roundtrip() {
        let (a, b) = SocketTransport::pair().unwrap();
        let f = frame();
        a.send(&f).unwrap();
        let g = b.recv().unwrap();
        assert_eq!(g.data.len(), f.data.len());
        assert_eq!(a.bytes_sent(), f.wire_len() as u64);
        // corrupt bytes through the socket also error at recv
        let mut bad = f.to_bytes();
        let last = bad.len() - 1;
        bad[last] ^= 0xff;
        b.send_raw(bad).unwrap();
        assert!(a.recv().is_err());
    }

    #[test]
    fn socket_rendezvous_listen_connect() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("higgs_transport_test_{}.sock", std::process::id()));
        let p2 = path.clone();
        let listener = std::thread::spawn(move || SocketTransport::listen(&p2));
        // connect retries while the listener binds
        let mut client = None;
        for _ in 0..200 {
            match SocketTransport::connect(&path) {
                Ok(c) => {
                    client = Some(c);
                    break;
                }
                Err(_) => std::thread::sleep(std::time::Duration::from_millis(5)),
            }
        }
        let client = client.expect("could not connect to test socket");
        let server = listener.join().unwrap().unwrap();
        client.send(&frame()).unwrap();
        assert_eq!(server.recv().unwrap().step, frame().step);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tcp_transport_roundtrip() {
        let (a, b) = TcpTransport::pair().unwrap();
        let f = frame();
        a.send(&f).unwrap();
        let g = b.recv().unwrap();
        assert_eq!(g.data.len(), f.data.len());
        assert_eq!(g.pos, f.pos);
        assert_eq!(a.bytes_sent(), f.wire_len() as u64);
        assert_eq!(a.frames_sent(), 1);
        // corrupt bytes through the socket also error at recv
        let mut bad = f.to_bytes();
        let last = bad.len() - 1;
        bad[last] ^= 0xff;
        b.send_raw(bad).unwrap();
        assert!(a.recv().is_err());
    }

    #[test]
    fn tcp_listen_connect_by_address() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        drop(listener);
        let a2 = addr.clone();
        let server = std::thread::spawn(move || TcpTransport::listen(&a2));
        // connect retries while the listener binds
        let mut client = None;
        for _ in 0..200 {
            match TcpTransport::connect(&addr) {
                Ok(c) => {
                    client = Some(c);
                    break;
                }
                Err(_) => std::thread::sleep(std::time::Duration::from_millis(5)),
            }
        }
        let client = client.expect("could not connect to test tcp port");
        let server = server.join().unwrap().unwrap();
        client.send(&frame()).unwrap();
        assert_eq!(server.recv().unwrap().step, frame().step);
        // peer hangup surfaces as Err, not a panic
        drop(client);
        assert!(server.recv().is_err());
    }
}
