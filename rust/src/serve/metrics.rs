//! Serving metrics: throughput / latency accounting for Table 1, plus
//! the backpressure signals continuous batching needs (queue depth,
//! admission-blocked time, queue-wait vs decode latency split).

use crate::util::stats::percentile;
use std::fmt;

/// One finished request's accounting. Latency is measured from
/// SUBMISSION (enqueue), not admission, and split into its queue-wait
/// and decode components so churn benches can attribute backpressure.
#[derive(Clone, Copy, Debug)]
pub struct CompletionStat {
    /// enqueue → completion (end-to-end, what the client sees)
    pub latency_ms: f64,
    /// enqueue → admission (time spent waiting for a slot)
    pub queue_ms: f64,
    /// admission → completion (prefill + decode)
    pub decode_ms: f64,
    pub generated: usize,
    pub prompt_len: usize,
}

/// One pipeline stage's time/traffic split (PERF.md §12). Busy/wait/
/// idle come from the deterministic bubble model — per decode round a
/// shard is busy for F chunks, waits `i` chunk-times for its first
/// input, and idles `N−1−i` chunk-times at the tail — while frames/
/// bytes are real counts off the shard's downstream transport.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardLane {
    pub busy_ms: f64,
    /// startup latency: waiting for the first micro-batch each round
    pub wait_ms: f64,
    /// drain latency: done while later shards still flush
    pub idle_ms: f64,
    pub frames_sent: u64,
    pub bytes_sent: u64,
}

/// Percentile summary of one request-lifecycle phase, sourced from the
/// daemon's span ring (`serve/spans.rs`, PERF.md §13) — per-phase
/// latency histograms rather than just the end-to-end split.
#[derive(Clone, Debug, Default)]
pub struct PhaseStats {
    /// "queue" (enqueue→admit), "prefill" (admit→first token),
    /// "decode" (first token→complete), or "total"
    pub phase: &'static str,
    /// completed spans contributing to this row
    pub count: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    pub completions: Vec<CompletionStat>,
    pub wall_secs: f64,
    pub decode_steps: u64,
    pub prefill_calls: u64,
    /// requests rejected at admission (e.g. empty prompts)
    pub rejected: u64,
    /// requests dropped by the router safety valve (stuck work that
    /// could not be admitted; never silently discarded)
    pub dropped: u64,
    /// high-water mark of the admission queue depth (backpressure)
    pub queue_peak: usize,
    /// total time the engine had queued requests it could not place in
    /// any slot (backpressure: admission wanted to run but was blocked)
    pub admission_blocked_ms: f64,
    /// engine-internal errors propagated out of `admit`/`step` (ABI
    /// drift, missing outputs, lease accounting bugs). Always ALSO
    /// returned as `Err` to the caller — this counter exists so a
    /// serving run's summary shows failures even when a driver retries
    /// or drops them.
    pub internal_errors: u64,
    /// pipeline fill/drain cost: per decode round, the makespan beyond
    /// the ideal `F·τ` a perfectly-overlapped round would take
    /// ((N−1)·τ per round; 0 for single-shard runs)
    pub pipeline_bubble_ms: f64,
    /// requests whose deadline expired before admission (daemon runs;
    /// each also got a typed `Error{Timeout}` reply)
    pub timeouts: u64,
    /// per-shard busy/wait/idle + traffic split; empty outside
    /// pipeline runs
    pub shard_lanes: Vec<ShardLane>,
    /// span-derived per-phase latency percentiles; empty outside
    /// daemon runs
    pub phases: Vec<PhaseStats>,
}

impl ServeMetrics {
    /// End-to-end generated-token throughput (tok/s) — Table 1's metric.
    pub fn tok_per_sec(&self) -> f64 {
        let toks: usize = self.completions.iter().map(|c| c.generated).sum();
        if self.wall_secs <= 0.0 {
            return 0.0;
        }
        toks as f64 / self.wall_secs
    }

    pub fn total_generated(&self) -> usize {
        self.completions.iter().map(|c| c.generated).sum()
    }

    fn latency_pct(&self, p: f64) -> f64 {
        let ls: Vec<f64> = self.completions.iter().map(|c| c.latency_ms).collect();
        if ls.is_empty() {
            0.0
        } else {
            percentile(&ls, p)
        }
    }

    pub fn latency_p50(&self) -> f64 {
        self.latency_pct(50.0)
    }

    pub fn latency_p95(&self) -> f64 {
        self.latency_pct(95.0)
    }

    pub fn latency_p99(&self) -> f64 {
        self.latency_pct(99.0)
    }

    /// Mean time completed requests spent queued before admission.
    pub fn mean_queue_ms(&self) -> f64 {
        if self.completions.is_empty() {
            return 0.0;
        }
        let s: f64 = self.completions.iter().map(|c| c.queue_ms).sum();
        s / self.completions.len() as f64
    }

    /// Mean time completed requests spent between admission and
    /// completion (prefill + decode).
    pub fn mean_decode_ms(&self) -> f64 {
        if self.completions.is_empty() {
            return 0.0;
        }
        let s: f64 = self.completions.iter().map(|c| c.decode_ms).sum();
        s / self.completions.len() as f64
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} reqs, {} toks, {:.1} tok/s, p50 {:.0} ms, p95 {:.0} ms, p99 {:.0} ms, \
             queue/decode {:.0}/{:.0} ms, {} decode steps, {} prefills",
            self.completions.len(),
            self.total_generated(),
            self.tok_per_sec(),
            self.latency_p50(),
            self.latency_p95(),
            self.latency_p99(),
            self.mean_queue_ms(),
            self.mean_decode_ms(),
            self.decode_steps,
            self.prefill_calls,
        );
        if self.queue_peak > 0 {
            s += &format!(", queue peak {}", self.queue_peak);
        }
        if self.admission_blocked_ms > 0.0 {
            s += &format!(", blocked {:.0} ms", self.admission_blocked_ms);
        }
        if self.rejected > 0 {
            s += &format!(", {} rejected", self.rejected);
        }
        if self.dropped > 0 {
            s += &format!(", {} DROPPED", self.dropped);
        }
        if self.timeouts > 0 {
            s += &format!(", {} timeouts", self.timeouts);
        }
        if self.internal_errors > 0 {
            s += &format!(", {} INTERNAL ERRORS", self.internal_errors);
        }
        if !self.shard_lanes.is_empty() {
            s += &format!(
                ", {} shards, bubble {:.0} ms",
                self.shard_lanes.len(),
                self.pipeline_bubble_ms
            );
        }
        s
    }

    /// Multi-line per-phase histogram table (one row per entry in
    /// `phases`); empty string when no spans were recorded.
    pub fn phase_report(&self) -> String {
        let mut s = String::new();
        for ph in &self.phases {
            s += &format!(
                "  phase {:<8} n={:<5} p50 {:>8.2} ms  p95 {:>8.2} ms  p99 {:>8.2} ms  max {:>8.2} ms\n",
                ph.phase, ph.count, ph.p50_ms, ph.p95_ms, ph.p99_ms, ph.max_ms
            );
        }
        s
    }
}

impl fmt::Display for ServeMetrics {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.summary())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stat(latency_ms: f64, queue_ms: f64, generated: usize) -> CompletionStat {
        CompletionStat {
            latency_ms,
            queue_ms,
            decode_ms: latency_ms - queue_ms,
            generated,
            prompt_len: 10,
        }
    }

    #[test]
    fn throughput_math() {
        let m = ServeMetrics {
            completions: vec![stat(100.0, 20.0, 50), stat(200.0, 40.0, 50)],
            wall_secs: 2.0,
            decode_steps: 100,
            prefill_calls: 2,
            ..Default::default()
        };
        assert!((m.tok_per_sec() - 50.0).abs() < 1e-9);
        assert_eq!(m.total_generated(), 100);
        assert!((m.latency_p50() - 100.0).abs() < 1e-9 || (m.latency_p50() - 200.0).abs() < 1e-9);
        assert!((m.mean_queue_ms() - 30.0).abs() < 1e-9);
        assert!((m.mean_decode_ms() - 120.0).abs() < 1e-9);
    }

    #[test]
    fn percentiles_ordered() {
        let m = ServeMetrics {
            completions: (1..=100).map(|i| stat(i as f64, 0.0, 1)).collect(),
            wall_secs: 1.0,
            ..Default::default()
        };
        assert!(m.latency_p50() <= m.latency_p95());
        assert!(m.latency_p95() <= m.latency_p99());
        assert!(m.latency_p99() > m.latency_p50());
        assert!(m.summary().contains("p99"));
    }

    #[test]
    fn empty_safe() {
        let m = ServeMetrics::default();
        assert_eq!(m.tok_per_sec(), 0.0);
        assert_eq!(m.latency_p50(), 0.0);
        assert_eq!(m.latency_p99(), 0.0);
        assert_eq!(m.mean_queue_ms(), 0.0);
        assert!(m.summary().contains("0 reqs"));
        // rejected/dropped/backpressure only surface when nonzero
        assert!(!m.summary().contains("rejected"));
        assert!(!m.summary().contains("queue peak"));
        assert!(!m.summary().contains("INTERNAL"));
        let m2 = ServeMetrics {
            rejected: 2,
            dropped: 1,
            queue_peak: 7,
            admission_blocked_ms: 12.0,
            internal_errors: 3,
            ..Default::default()
        };
        assert!(m2.summary().contains("2 rejected"));
        assert!(m2.summary().contains("1 DROPPED"));
        assert!(m2.summary().contains("3 INTERNAL ERRORS"));
        assert!(!m2.summary().contains("timeouts"));
        let m3 = ServeMetrics { timeouts: 4, ..Default::default() };
        assert!(m3.summary().contains("4 timeouts"));
        assert!(m2.summary().contains("queue peak 7"));
        assert!(m2.summary().contains("blocked 12 ms"));
        // Display delegates to summary
        assert_eq!(format!("{m2}"), m2.summary());
    }

    #[test]
    fn shard_lanes_surface_in_summary() {
        let mut m = ServeMetrics::default();
        assert!(!m.summary().contains("shards"));
        m.shard_lanes = vec![
            ShardLane { busy_ms: 10.0, wait_ms: 0.0, idle_ms: 1.0, frames_sent: 4, bytes_sent: 99 },
            ShardLane { busy_ms: 10.0, wait_ms: 1.0, idle_ms: 0.0, frames_sent: 4, bytes_sent: 99 },
        ];
        m.pipeline_bubble_ms = 2.0;
        assert!(m.summary().contains("2 shards, bubble 2 ms"));
    }

    #[test]
    fn phase_report_rows() {
        let mut m = ServeMetrics::default();
        assert!(m.phase_report().is_empty());
        m.phases = vec![
            PhaseStats { phase: "queue", count: 3, p50_ms: 1.0, p95_ms: 2.0, p99_ms: 2.0, max_ms: 2.0 },
            PhaseStats { phase: "decode", count: 3, p50_ms: 5.0, p95_ms: 9.0, p99_ms: 9.0, max_ms: 9.0 },
        ];
        let rep = m.phase_report();
        assert!(rep.contains("phase queue"));
        assert!(rep.contains("phase decode"));
        assert_eq!(rep.lines().count(), 2);
    }
}
