//! Serving metrics: throughput / latency accounting for Table 1.

use crate::util::stats::percentile;

#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    /// per-request (latency_ms, generated tokens, prompt tokens)
    pub completions: Vec<(f64, usize, usize)>,
    pub wall_secs: f64,
    pub decode_steps: u64,
    pub prefill_calls: u64,
    /// requests rejected at admission (e.g. empty prompts)
    pub rejected: u64,
    /// requests dropped by the router safety valve (stuck work that
    /// could not be admitted; never silently discarded)
    pub dropped: u64,
}

impl ServeMetrics {
    /// End-to-end generated-token throughput (tok/s) — Table 1's metric.
    pub fn tok_per_sec(&self) -> f64 {
        let toks: usize = self.completions.iter().map(|c| c.1).sum();
        if self.wall_secs <= 0.0 {
            return 0.0;
        }
        toks as f64 / self.wall_secs
    }

    pub fn total_generated(&self) -> usize {
        self.completions.iter().map(|c| c.1).sum()
    }

    pub fn latency_p50(&self) -> f64 {
        let ls: Vec<f64> = self.completions.iter().map(|c| c.0).collect();
        if ls.is_empty() {
            0.0
        } else {
            percentile(&ls, 50.0)
        }
    }

    pub fn latency_p95(&self) -> f64 {
        let ls: Vec<f64> = self.completions.iter().map(|c| c.0).collect();
        if ls.is_empty() {
            0.0
        } else {
            percentile(&ls, 95.0)
        }
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} reqs, {} toks, {:.1} tok/s, p50 {:.0} ms, p95 {:.0} ms, {} decode steps, {} prefills",
            self.completions.len(),
            self.total_generated(),
            self.tok_per_sec(),
            self.latency_p50(),
            self.latency_p95(),
            self.decode_steps,
            self.prefill_calls,
        );
        if self.rejected > 0 {
            s += &format!(", {} rejected", self.rejected);
        }
        if self.dropped > 0 {
            s += &format!(", {} DROPPED", self.dropped);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let m = ServeMetrics {
            completions: vec![(100.0, 50, 10), (200.0, 50, 10)],
            wall_secs: 2.0,
            decode_steps: 100,
            prefill_calls: 2,
            ..Default::default()
        };
        assert!((m.tok_per_sec() - 50.0).abs() < 1e-9);
        assert_eq!(m.total_generated(), 100);
        assert!((m.latency_p50() - 100.0).abs() < 1e-9 || (m.latency_p50() - 200.0).abs() < 1e-9);
    }

    #[test]
    fn empty_safe() {
        let m = ServeMetrics::default();
        assert_eq!(m.tok_per_sec(), 0.0);
        assert_eq!(m.latency_p50(), 0.0);
        assert!(m.summary().contains("0 reqs"));
        // rejected/dropped only surface when nonzero
        assert!(!m.summary().contains("rejected"));
        let m2 = ServeMetrics { rejected: 2, dropped: 1, ..Default::default() };
        assert!(m2.summary().contains("2 rejected"));
        assert!(m2.summary().contains("1 DROPPED"));
    }
}
