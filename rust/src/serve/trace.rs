//! Workload traces: the synthetic request streams the benchmarks replay
//! (the stand-in for production serving traces).

use crate::data::{Corpus, Split};
use crate::util::prng::Rng;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// offset from trace start, in milliseconds (0 = all-at-once)
    pub arrival_ms: u64,
}

/// A request plus the instant it entered the serving system. End-to-end
/// latency is measured from THIS timestamp (submission), not from
/// admission — otherwise queueing delay under churn is invisible.
#[derive(Clone, Debug)]
pub struct QueuedRequest {
    pub req: Request,
    pub enqueued: Instant,
}

impl QueuedRequest {
    /// Stamp a request as entering the system now.
    pub fn now(req: Request) -> Self {
        QueuedRequest { req, enqueued: Instant::now() }
    }
}

impl From<Request> for QueuedRequest {
    fn from(req: Request) -> Self {
        QueuedRequest::now(req)
    }
}

#[derive(Clone, Debug)]
pub struct TraceConfig {
    pub n_requests: usize,
    pub prompt_len: (usize, usize),
    pub max_new: (usize, usize),
    /// mean inter-arrival gap; 0 = closed-loop (all arrive at t=0)
    pub mean_gap_ms: u64,
    pub seed: u64,
    /// fraction of requests drawing from `long_prompt_len` instead of
    /// `prompt_len` — the churn scenarios' mixed-prompt-length knob
    pub long_frac: f64,
    pub long_prompt_len: (usize, usize),
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            n_requests: 32,
            prompt_len: (8, 24),
            max_new: (16, 32),
            mean_gap_ms: 0,
            seed: 0xBEEF,
            long_frac: 0.0,
            long_prompt_len: (48, 64),
        }
    }
}

/// Generate a trace of grammar-text prompts.
pub fn generate_trace(cfg: &TraceConfig, corpus: &Corpus) -> Vec<Request> {
    let mut rng = Rng::from_stream(cfg.seed, "trace");
    let mut arrival = 0u64;
    (0..cfg.n_requests)
        .map(|i| {
            // short-circuit keeps long_frac == 0.0 traces byte-identical
            // to pre-churn traces (no extra rng draw)
            let (lo, hi) = if cfg.long_frac > 0.0 && rng.coin(cfg.long_frac) {
                cfg.long_prompt_len
            } else {
                cfg.prompt_len
            };
            let plen = lo + rng.below(hi - lo + 1);
            let new = cfg.max_new.0 + rng.below(cfg.max_new.1 - cfg.max_new.0 + 1);
            let seq = corpus.sequence(Split::Val, 90_000 + i);
            let prompt: Vec<i32> = seq[..plen.min(seq.len())].iter().map(|&t| t as i32).collect();
            if cfg.mean_gap_ms > 0 {
                // exponential-ish inter-arrival
                let u = rng.uniform().max(1e-9);
                arrival += (-(u.ln()) * cfg.mean_gap_ms as f64) as u64;
            }
            Request { id: i as u64, prompt, max_new: new, arrival_ms: arrival }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_shapes() {
        let corpus = Corpus::new(256, 96, 1);
        let cfg = TraceConfig { n_requests: 10, ..Default::default() };
        let t = generate_trace(&cfg, &corpus);
        assert_eq!(t.len(), 10);
        for r in &t {
            assert!(r.prompt.len() >= 8 && r.prompt.len() <= 24);
            assert!(r.max_new >= 16 && r.max_new <= 32);
            assert_eq!(r.arrival_ms, 0);
        }
    }

    #[test]
    fn open_loop_arrivals_increase() {
        let corpus = Corpus::new(256, 96, 1);
        let cfg = TraceConfig { n_requests: 20, mean_gap_ms: 5, ..Default::default() };
        let t = generate_trace(&cfg, &corpus);
        assert!(t.windows(2).all(|w| w[1].arrival_ms >= w[0].arrival_ms));
        assert!(t.last().unwrap().arrival_ms > 0);
    }

    #[test]
    fn long_prompt_mixture() {
        let corpus = Corpus::new(256, 96, 1);
        // long_frac = 1.0: every prompt draws from the long range
        let all_long = TraceConfig {
            n_requests: 12,
            long_frac: 1.0,
            long_prompt_len: (40, 60),
            ..Default::default()
        };
        for r in generate_trace(&all_long, &corpus) {
            assert!(r.prompt.len() >= 40 && r.prompt.len() <= 60, "{}", r.prompt.len());
        }
        // mixed: both populations show up
        let mixed = TraceConfig {
            n_requests: 64,
            long_frac: 0.5,
            long_prompt_len: (40, 60),
            ..Default::default()
        };
        let t = generate_trace(&mixed, &corpus);
        assert!(t.iter().any(|r| r.prompt.len() <= 24));
        assert!(t.iter().any(|r| r.prompt.len() >= 40));
    }

    #[test]
    fn queued_request_wraps() {
        let r = Request { id: 9, prompt: vec![1], max_new: 2, arrival_ms: 0 };
        let q: QueuedRequest = r.clone().into();
        assert_eq!(q.req.id, 9);
        assert!(q.enqueued.elapsed().as_secs() < 60);
    }

    #[test]
    fn deterministic() {
        let corpus = Corpus::new(256, 96, 1);
        let cfg = TraceConfig::default();
        let a = generate_trace(&cfg, &corpus);
        let b = generate_trace(&cfg, &corpus);
        assert_eq!(a.len(), b.len());
        assert_eq!(a[3].prompt, b[3].prompt);
    }
}
