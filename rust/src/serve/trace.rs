//! Workload traces: the synthetic request streams the benchmarks replay
//! (the stand-in for production serving traces).

use crate::data::{Corpus, Split};
use crate::util::prng::Rng;
use std::time::{Duration, Instant};

/// The serving stack's time source. All timestamps downstream
/// ([`QueuedRequest::enqueued_ms`], the batcher deadline, the engine's
/// latency split) are f64 milliseconds on ONE clock, so the whole
/// admission path can run against either real time or a deterministic
/// virtual clock (`serve-bench --churn --virtual-clock`: open-loop
/// arrival replay with no wall-clock sleeps, one tick per decode step).
#[derive(Clone, Debug)]
pub enum Clock {
    /// Real time: `now_ms` is wall time elapsed since construction.
    Wall { t0: Instant },
    /// Deterministic virtual time: advances only via [`Clock::advance`]
    /// / [`Clock::sleep_until`]. Never sleeps.
    Virtual { now_ms: f64 },
}

impl Clock {
    pub fn wall() -> Clock {
        Clock::Wall { t0: Instant::now() }
    }

    pub fn virtual_at(now_ms: f64) -> Clock {
        Clock::Virtual { now_ms }
    }

    pub fn is_virtual(&self) -> bool {
        matches!(self, Clock::Virtual { .. })
    }

    /// Milliseconds since this clock's origin.
    pub fn now_ms(&self) -> f64 {
        match self {
            Clock::Wall { t0 } => t0.elapsed().as_secs_f64() * 1e3,
            Clock::Virtual { now_ms } => *now_ms,
        }
    }

    /// Charge `ms` of simulated work to a virtual clock. On a wall
    /// clock this is a no-op — real time advances on its own.
    pub fn advance(&mut self, ms: f64) {
        if let Clock::Virtual { now_ms } = self {
            *now_ms += ms;
            crate::util::sync::note_virtual_now_ms(*now_ms);
        }
    }

    /// Block until roughly `target_ms`, bounded by `cap_ms` per call so
    /// callers can keep polling. Wall: one short sleep (≥ 1 ms).
    /// Virtual: jump straight to the target — no sleeping, which is the
    /// entire point of virtual replay.
    pub fn sleep_until(&mut self, target_ms: f64, cap_ms: f64) {
        match self {
            Clock::Wall { t0 } => {
                let now = t0.elapsed().as_secs_f64() * 1e3;
                let wait = (target_ms - now).max(0.0).min(cap_ms);
                std::thread::sleep(Duration::from_millis((wait as u64).max(1)));
            }
            Clock::Virtual { now_ms } => {
                *now_ms = now_ms.max(target_ms);
                crate::util::sync::note_virtual_now_ms(*now_ms);
            }
        }
    }
}

#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// offset from trace start, in milliseconds (0 = all-at-once)
    pub arrival_ms: u64,
}

/// A request plus the [`Clock`] timestamp at which it entered the
/// serving system. End-to-end latency is measured from THIS timestamp
/// (submission), not from admission — otherwise queueing delay under
/// churn is invisible.
#[derive(Clone, Debug)]
pub struct QueuedRequest {
    pub req: Request,
    /// submission time in ms on the engine/batcher's shared [`Clock`]
    pub enqueued_ms: f64,
}

impl QueuedRequest {
    /// Stamp a request as entering the system at `now_ms` (the caller's
    /// clock reading — wall or virtual).
    pub fn at(req: Request, now_ms: f64) -> Self {
        QueuedRequest { req, enqueued_ms: now_ms }
    }
}

#[derive(Clone, Debug)]
pub struct TraceConfig {
    pub n_requests: usize,
    pub prompt_len: (usize, usize),
    pub max_new: (usize, usize),
    /// mean inter-arrival gap; 0 = closed-loop (all arrive at t=0)
    pub mean_gap_ms: u64,
    pub seed: u64,
    /// fraction of requests drawing from `long_prompt_len` instead of
    /// `prompt_len` — the churn scenarios' mixed-prompt-length knob
    pub long_frac: f64,
    pub long_prompt_len: (usize, usize),
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            n_requests: 32,
            prompt_len: (8, 24),
            max_new: (16, 32),
            mean_gap_ms: 0,
            seed: 0xBEEF,
            long_frac: 0.0,
            long_prompt_len: (48, 64),
        }
    }
}

/// Generate a trace of grammar-text prompts.
pub fn generate_trace(cfg: &TraceConfig, corpus: &Corpus) -> Vec<Request> {
    let mut rng = Rng::from_stream(cfg.seed, "trace");
    let mut arrival = 0u64;
    (0..cfg.n_requests)
        .map(|i| {
            // short-circuit keeps long_frac == 0.0 traces byte-identical
            // to pre-churn traces (no extra rng draw)
            let (lo, hi) = if cfg.long_frac > 0.0 && rng.coin(cfg.long_frac) {
                cfg.long_prompt_len
            } else {
                cfg.prompt_len
            };
            let plen = lo + rng.below(hi - lo + 1);
            let new = cfg.max_new.0 + rng.below(cfg.max_new.1 - cfg.max_new.0 + 1);
            let seq = corpus.sequence(Split::Val, 90_000 + i);
            let prompt: Vec<i32> = seq[..plen.min(seq.len())].iter().map(|&t| t as i32).collect();
            if cfg.mean_gap_ms > 0 {
                // exponential-ish inter-arrival
                let u = rng.uniform().max(1e-9);
                arrival += (-(u.ln()) * cfg.mean_gap_ms as f64) as u64;
            }
            Request { id: i as u64, prompt, max_new: new, arrival_ms: arrival }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_shapes() {
        let corpus = Corpus::new(256, 96, 1);
        let cfg = TraceConfig { n_requests: 10, ..Default::default() };
        let t = generate_trace(&cfg, &corpus);
        assert_eq!(t.len(), 10);
        for r in &t {
            assert!(r.prompt.len() >= 8 && r.prompt.len() <= 24);
            assert!(r.max_new >= 16 && r.max_new <= 32);
            assert_eq!(r.arrival_ms, 0);
        }
    }

    #[test]
    fn open_loop_arrivals_increase() {
        let corpus = Corpus::new(256, 96, 1);
        let cfg = TraceConfig { n_requests: 20, mean_gap_ms: 5, ..Default::default() };
        let t = generate_trace(&cfg, &corpus);
        assert!(t.windows(2).all(|w| w[1].arrival_ms >= w[0].arrival_ms));
        assert!(t.last().unwrap().arrival_ms > 0);
    }

    #[test]
    fn long_prompt_mixture() {
        let corpus = Corpus::new(256, 96, 1);
        // long_frac = 1.0: every prompt draws from the long range
        let all_long = TraceConfig {
            n_requests: 12,
            long_frac: 1.0,
            long_prompt_len: (40, 60),
            ..Default::default()
        };
        for r in generate_trace(&all_long, &corpus) {
            assert!(r.prompt.len() >= 40 && r.prompt.len() <= 60, "{}", r.prompt.len());
        }
        // mixed: both populations show up
        let mixed = TraceConfig {
            n_requests: 64,
            long_frac: 0.5,
            long_prompt_len: (40, 60),
            ..Default::default()
        };
        let t = generate_trace(&mixed, &corpus);
        assert!(t.iter().any(|r| r.prompt.len() <= 24));
        assert!(t.iter().any(|r| r.prompt.len() >= 40));
    }

    #[test]
    fn queued_request_wraps() {
        let r = Request { id: 9, prompt: vec![1], max_new: 2, arrival_ms: 0 };
        let q = QueuedRequest::at(r, 12.5);
        assert_eq!(q.req.id, 9);
        assert_eq!(q.enqueued_ms, 12.5);
    }

    #[test]
    fn virtual_clock_is_deterministic() {
        let mut c = Clock::virtual_at(0.0);
        assert!(c.is_virtual());
        assert_eq!(c.now_ms(), 0.0);
        c.advance(1.5);
        assert_eq!(c.now_ms(), 1.5);
        // sleep_until jumps without sleeping, and never moves backwards
        c.sleep_until(10.0, 5.0);
        assert_eq!(c.now_ms(), 10.0);
        c.sleep_until(4.0, 5.0);
        assert_eq!(c.now_ms(), 10.0);
    }

    #[test]
    fn wall_clock_monotonic() {
        let c = Clock::wall();
        assert!(!c.is_virtual());
        let a = c.now_ms();
        let b = c.now_ms();
        assert!(b >= a && a >= 0.0);
        // advance is a no-op on a wall clock
        let mut c = c;
        c.advance(1e9);
        assert!(c.now_ms() < 1e9);
    }

    #[test]
    fn deterministic() {
        let corpus = Corpus::new(256, 96, 1);
        let cfg = TraceConfig::default();
        let a = generate_trace(&cfg, &corpus);
        let b = generate_trace(&cfg, &corpus);
        assert_eq!(a.len(), b.len());
        assert_eq!(a[3].prompt, b[3].prompt);
    }
}
