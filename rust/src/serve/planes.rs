//! `PlaneStore` — the decode-once cache of dense layer planes that
//! serving cold start provisions parameters from.
//!
//! Engine construction needs every quantized layer's dense weights
//! TWICE: once for the decode-manifest params and once for the
//! prefill-manifest params (prefill always runs the dense graph on
//! dequantized weights). Before this store existed each
//! `build_params` call decoded every layer for itself, so the
//! dominant cost of an artifact cold start was paid double. Now
//! [`PlaneStore::build_for`] takes the union of `.w` params across
//! all consuming manifests, decodes each covered layer exactly once
//! in one pool fan-out (each layer's own decode is block-parallel
//! inline via the pool's re-entrancy guard), and
//! [`crate::serve::Backend::build_params_with`] pulls finished planes
//! out of the store via [`PlaneStore::claim`] — which counts how many
//! manifests reference each layer, clones for every consumer but the
//! last, and MOVES the tensor to the last one. A single-manifest
//! store (the `build_params_from` wrapper) therefore keeps the old
//! zero-copy handoff, and a decode+prefill store pays exactly one
//! clone per layer instead of one decode per manifest.
//!
//! The decode-once contract is instrumented: the store counts its
//! decodes ([`PlaneStore::decode_count`]) and the kernel-level
//! [`crate::quant::decode::dense_decode_count`] counter lets tests
//! and `micro_hotpaths` assert that a whole engine-construction pass
//! performed exactly one dense decode per quantized layer.
//!
//! All three [`QuantSource`] variants flow through here — in-memory
//! model, loaded artifact, and on-disk
//! [`crate::quant::reader::ArtifactReader`] (whose per-layer ranged
//! reads happen inside the same fan-out, so a lazy cold start
//! overlaps I/O, checksum verification, and decode across layers).

use super::backend::QuantSource;
use crate::model::Manifest;
use crate::tensor::Tensor;
use crate::util::sync::{rank, AuditMutex};
use anyhow::Result;
use std::collections::HashMap;

/// Dense decoded layer planes keyed by layer base name (the
/// manifest's `<base>.w`), each tagged with how many claims remain.
pub struct PlaneStore {
    /// (plane, remaining claims); the entry is removed — and the
    /// tensor moved out — on its last claim
    planes: AuditMutex<HashMap<String, (Tensor, usize)>>,
    decoded: usize,
}

impl PlaneStore {
    /// A store with no planes (dense serving without a quantized
    /// source).
    pub fn empty() -> PlaneStore {
        PlaneStore {
            planes: AuditMutex::new("serve.planes", rank::PLANES, HashMap::new()),
            decoded: 0,
        }
    }

    /// Decode every layer that appears as a `<base>.w` param in ANY of
    /// `manifests` and is covered by `src` — each exactly once, in one
    /// pool fan-out over the deduplicated union. Each plane's claim
    /// budget is the number of manifests that reference it, so
    /// [`PlaneStore::claim`] can move (not clone) the tensor to its
    /// last consumer.
    pub fn build_for(src: QuantSource<'_>, manifests: &[&Manifest]) -> Result<PlaneStore> {
        let mut names: Vec<&str> = Vec::new();
        let mut uses: HashMap<&str, usize> = HashMap::new();
        for man in manifests {
            for spec in &man.params {
                if let Some(base) = spec.name.strip_suffix(".w") {
                    if src.covers(base) {
                        let n = uses.entry(base).or_insert(0);
                        if *n == 0 {
                            names.push(base);
                        }
                        *n += 1;
                    }
                }
            }
        }
        let decoded: Vec<Result<Tensor>> =
            crate::util::pool::par_map(names.len(), |i| src.dense_weight(names[i]));
        let mut planes = HashMap::with_capacity(names.len());
        for (base, t) in names.iter().zip(decoded) {
            planes.insert(base.to_string(), (t?, uses[base]));
        }
        Ok(PlaneStore {
            decoded: planes.len(),
            planes: AuditMutex::new("serve.planes", rank::PLANES, planes),
        })
    }

    /// Take one claim on layer `base`'s dense plane: a clone for every
    /// consumer but the last, the owned tensor (no copy) for the last.
    /// `None` once the claim budget is spent or if the store never
    /// decoded the layer — callers fall back to decoding from the
    /// source, so over-claiming stays correct (just not decode-once).
    pub fn claim(&self, base: &str) -> Option<Tensor> {
        let mut planes = self.planes.lock();
        if let Some((t, remaining)) = planes.get_mut(base) {
            if *remaining > 1 {
                *remaining -= 1;
                return Some(t.clone());
            }
        } else {
            return None;
        }
        // last claim: move the tensor out instead of cloning
        planes.remove(base).map(|(t, _)| t)
    }

    /// Whether the store still holds a plane for `base` (claims left).
    pub fn contains(&self, base: &str) -> bool {
        self.planes.lock().contains_key(base)
    }

    /// How many layer decodes this store performed at build — by
    /// construction exactly one per covered layer, which is what makes
    /// it the decode-once witness in tests.
    pub fn decode_count(&self) -> usize {
        self.decoded
    }

    pub fn is_empty(&self) -> bool {
        self.decoded == 0
    }

    /// Number of layers decoded at build (not the remaining claims).
    pub fn len(&self) -> usize {
        self.decoded
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grids::registry::GridRegistry;
    use crate::grids::GridKind;
    use crate::model::fixture;
    use crate::quant::higgs::HiggsQuantizer;
    use crate::quant::QuantizedModel;

    #[test]
    fn union_decodes_once_and_claims_count_manifests() {
        let w = fixture::tiny_weights(3);
        let reg = GridRegistry::new();
        let q = HiggsQuantizer::new(reg.get(GridKind::Higgs, 16, 2), 16, 1);
        let qm = QuantizedModel::quantize_all(&w, &q);
        let man =
            Manifest::parse(&fixture::dense_manifest_text(&fixture::tiny_config())).unwrap();
        let before = crate::quant::decode::dense_decode_count();
        // the same manifest twice: the union still decodes each layer
        // once, and each plane carries TWO claims
        let store = PlaneStore::build_for(QuantSource::Model(&qm), &[&man, &man]).unwrap();
        let delta = crate::quant::decode::dense_decode_count() - before;
        assert_eq!(store.decode_count(), qm.layers.len());
        // NOTE: other tests in this binary may decode concurrently, so
        // only a lower bound is safe on the global counter here; the
        // exact-delta assertion lives in tests/prop_reader.rs where
        // decoding tests serialize on a shared lock.
        assert!(delta >= qm.layers.len() as u64);
        for l in &qm.layers {
            let want = l.dequantize().data;
            let first = store.claim(&l.name).expect("first claim (clone)");
            assert!(store.contains(&l.name), "one claim left after the first");
            let second = store.claim(&l.name).expect("second claim (move)");
            assert_eq!(first.data, want, "{}", l.name);
            assert_eq!(second.data, want, "{}", l.name);
            // budget spent: further claims miss (callers fall back)
            assert!(store.claim(&l.name).is_none());
            assert!(!store.contains(&l.name));
        }
        assert!(store.claim("nonexistent").is_none());
        assert!(!store.is_empty());
        assert_eq!(store.len(), qm.layers.len());
    }

    #[test]
    fn empty_store_for_dense_serving() {
        let s = PlaneStore::empty();
        assert!(s.is_empty());
        assert_eq!(s.decode_count(), 0);
        assert!(s.claim("anything").is_none());
    }
}
