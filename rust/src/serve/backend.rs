//! Weight backends: how model weights are represented on the decode
//! path. Each backend names a decode artifact family and knows how to
//! assemble the executable's parameter list from a (dense, quantized)
//! model pair — the rust side of Table 1's kernel comparison.

use crate::model::manifest::{DType, Manifest};
use crate::model::Weights;
use crate::quant::{QuantData, QuantizedModel};
use crate::runtime::HostArg;
use anyhow::{bail, Context, Result};

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Backend {
    /// dense f32 GEMM (the FP16 baseline of Table 1)
    Dense,
    /// fused scale/zero uniform dequant (MARLIN stand-in), b=4
    Uniform4,
    /// unfused scalar LUT (NF4/bitsandbytes stand-in), n=16
    NfLut4,
    /// fused vector-LUT Pallas kernel + activation RHT (FLUTE/HIGGS)
    Flute { bits: u32 },
    /// mixed-precision model (§5 dynamic allocation): every layer
    /// carries its own grid/bits, served through the dense decode
    /// executable on per-layer dequantized weights (the LUT kernels
    /// take ONE global grid, which a mixed model does not have)
    Mixed,
}

impl Backend {
    pub fn label(&self) -> String {
        match self {
            Backend::Dense => "fp16".into(),
            Backend::Uniform4 => "marlin(uniform4)".into(),
            Backend::NfLut4 => "nf4".into(),
            Backend::Flute { bits } => format!("flute{bits}"),
            Backend::Mixed => "mixed".into(),
        }
    }

    /// The decode artifact name for (cfg, batch).
    pub fn decode_artifact(&self, cfg_name: &str, batch: usize) -> String {
        match self {
            Backend::Dense | Backend::Mixed => format!("decode_dense_{cfg_name}_b{batch}"),
            Backend::Uniform4 => format!("decode_uniform_b4_{cfg_name}_b{batch}"),
            Backend::NfLut4 => format!("decode_nf_n16_{cfg_name}_b{batch}"),
            Backend::Flute { bits } => {
                let n = 1usize << (2 * bits); // p=2 grids
                format!("decode_flute_p2_n{n}_rht_{cfg_name}_b{batch}")
            }
        }
    }

    /// Prefill always runs the dense artifact on (de)quantized weights —
    /// numerically identical to the backend's representation (App. G).
    pub fn prefill_artifact(&self, cfg_name: &str, batch: usize) -> String {
        format!("prefill_dense_{cfg_name}_b{batch}")
    }

    /// Assemble the decode executable's `param` arguments in manifest
    /// order from full-precision weights + the quantized model.
    pub fn build_params(
        &self,
        man: &Manifest,
        weights: &Weights,
        qmodel: Option<&QuantizedModel>,
    ) -> Result<Vec<HostArg>> {
        // Per-layer dense weights are the expensive params (a full
        // blocked decode each): fan them out over the pool up front
        // instead of decoding layers one-by-one on the calling thread.
        // Each layer's own decode is block-parallel too, but at engine
        // construction the per-layer fan-out is what overlaps small
        // and large layers (nested par_for runs inline via the pool's
        // re-entrancy guard). This is the Mixed serve-bench cold-start
        // path.
        let mut dense_w: Vec<Option<crate::tensor::Tensor>> = if qmodel.is_some() {
            let specs = &man.params;
            crate::util::pool::par_map(specs.len(), |i| {
                let base = specs[i].name.strip_suffix(".w")?;
                let ql = qmodel?.get(base)?;
                Some(ql.dequantize())
            })
        } else {
            // no quantized model → nothing to pre-decode; skip the
            // pool fan-out instead of spawning workers for all-None
            vec![None; man.params.len()]
        };
        let mut out = Vec::with_capacity(man.params.len());
        for (pi, spec) in man.params.iter().enumerate() {
            let arg = if spec.name == "lut" {
                let qm = qmodel.context("lut param but no quantized model")?;
                qm.layers.first().context("empty qmodel")?;
                // the decode executable bakes in ONE global grid: a
                // mixed-precision model (per-layer grids) would silently
                // decode every non-matching layer's codes against the
                // wrong LUT — reject it here instead
                let grid = qm.shared_lut_grid().context(
                    "decode artifact expects a single shared LUT grid, but the \
                     quantized model is mixed-precision; serve it with \
                     Backend::Mixed (dense decode on per-layer dequantized \
                     weights) instead",
                )?;
                if grid.n * grid.p != spec.numel() {
                    bail!(
                        "grid {}x{} does not match lut param {:?}",
                        grid.n,
                        grid.p,
                        spec.dims
                    );
                }
                HostArg::F32(grid.points.clone(), spec.dims.clone())
            } else if let Some(base) = spec.name.strip_suffix(".w") {
                // dense linear weight: use dequantized values if we have
                // a quantized model (keeps dense-backend comparisons
                // honest; pre-decoded in the pool fan-out above), else
                // original
                let t = match dense_w[pi].take() {
                    Some(t) => t,
                    None => weights.linear(base).context("missing linear")?.clone(),
                };
                HostArg::F32(t.data, spec.dims.clone())
            } else if let Some(base) = spec.name.strip_suffix(".codes") {
                let ql = lookup(qmodel, base)?;
                let codes: &[u32] = match &ql.data {
                    QuantData::Lut { codes, .. } => codes,
                    QuantData::Uniform { codes, .. } => codes,
                };
                if codes.len() != spec.numel() {
                    bail!("{}: codes len {} vs {:?}", spec.name, codes.len(), spec.dims);
                }
                HostArg::I32(codes.iter().map(|&c| c as i32).collect(), spec.dims.clone())
            } else if let Some(base) = spec.name.strip_suffix(".scales") {
                let ql = lookup(qmodel, base)?;
                match &ql.data {
                    QuantData::Lut { scales, .. } => {
                        HostArg::F32(scales.clone(), spec.dims.clone())
                    }
                    _ => bail!("{}: not LUT data", spec.name),
                }
            } else if let Some(base) = spec.name.strip_suffix(".scale") {
                let ql = lookup(qmodel, base)?;
                match &ql.data {
                    QuantData::Uniform { steps, .. } => {
                        HostArg::F32(steps.clone(), spec.dims.clone())
                    }
                    _ => bail!("{}: not uniform data", spec.name),
                }
            } else if let Some(base) = spec.name.strip_suffix(".zero") {
                let ql = lookup(qmodel, base)?;
                match &ql.data {
                    QuantData::Uniform { zeros, .. } => {
                        HostArg::F32(zeros.clone(), spec.dims.clone())
                    }
                    _ => bail!("{}: not uniform data", spec.name),
                }
            } else if let Some(base) = spec.name.strip_suffix(".signs") {
                let ql = lookup(qmodel, base)?;
                match &ql.data {
                    QuantData::Lut { signs: Some(s), .. } => {
                        HostArg::F32(s.clone(), spec.dims.clone())
                    }
                    _ => bail!("{}: layer has no RHT signs", spec.name),
                }
            } else {
                // embed / norms: full precision
                let t = weights
                    .get(&spec.name)
                    .with_context(|| format!("weights missing {}", spec.name))?;
                if spec.dtype != DType::F32 {
                    bail!("{}: expected f32", spec.name);
                }
                HostArg::F32(t.data.clone(), spec.dims.clone())
            };
            out.push(arg);
        }
        Ok(out)
    }
}

fn lookup<'a>(
    qmodel: Option<&'a QuantizedModel>,
    base: &str,
) -> Result<&'a crate::quant::QuantizedLayer> {
    qmodel
        .context("quantized param but no quantized model")?
        .get(base)
        .with_context(|| format!("quantized model missing layer {base}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::grids::registry::GridRegistry;
    use crate::grids::GridKind;
    use crate::quant::higgs::HiggsQuantizer;
    use crate::quant::Quantizer;

    #[test]
    fn artifact_names() {
        assert_eq!(Backend::Dense.decode_artifact("base", 4), "decode_dense_base_b4");
        assert_eq!(
            Backend::Flute { bits: 3 }.decode_artifact("base", 16),
            "decode_flute_p2_n64_rht_base_b16"
        );
        assert_eq!(
            Backend::Uniform4.decode_artifact("base", 1),
            "decode_uniform_b4_base_b1"
        );
        assert_eq!(Backend::NfLut4.decode_artifact("base", 1), "decode_nf_n16_base_b1");
        // mixed models are served through the dense decode executable
        assert_eq!(Backend::Mixed.decode_artifact("base", 1), "decode_dense_base_b1");
    }

    #[test]
    fn labels_distinct() {
        let all = [
            Backend::Dense,
            Backend::Uniform4,
            Backend::NfLut4,
            Backend::Flute { bits: 2 },
            Backend::Flute { bits: 4 },
            Backend::Mixed,
        ];
        let labels: std::collections::HashSet<String> =
            all.iter().map(|b| b.label()).collect();
        assert_eq!(labels.len(), all.len());
    }

    use crate::model::fixture;

    fn tiny_weights() -> Weights {
        fixture::tiny_weights(5)
    }

    /// Quantize the tiny model with ALTERNATING grids (a mixed model).
    fn mixed_model(w: &Weights) -> QuantizedModel {
        let reg = GridRegistry::new();
        let q2 = HiggsQuantizer::new(reg.get(GridKind::Higgs, 16, 2), 16, 1);
        let q4 = HiggsQuantizer::new(reg.get(GridKind::Higgs, 256, 2), 16, 1);
        let names = w.linear_names();
        let assignment: Vec<(String, &dyn Quantizer)> = names
            .iter()
            .enumerate()
            .map(|(i, n)| {
                let q: &dyn Quantizer = if i % 2 == 0 { &q2 } else { &q4 };
                (n.clone(), q)
            })
            .collect();
        QuantizedModel::quantize_mixed(w, &assignment)
    }

    #[test]
    fn mixed_backend_builds_dense_params_from_mixed_model() {
        let w = tiny_weights();
        let qm = mixed_model(&w);
        assert!(qm.shared_lut_grid().is_none(), "model should be mixed");
        // the dense/Mixed manifest: every param as the dense graph sees it
        let cfg = fixture::tiny_config();
        let mut text = String::from("artifact decode_dense_tiny_b1\n");
        text += &format!("param embed f32 {},{}\n", cfg.vocab, cfg.d_model);
        for (n, (k, m)) in cfg.linear_shapes() {
            text += &format!("param {n}.w f32 {k},{m}\n");
        }
        let man = Manifest::parse(&text).unwrap();
        let args = Backend::Mixed.build_params(&man, &w, Some(&qm)).unwrap();
        assert_eq!(args.len(), man.params.len());
        // each linear param is the layer's OWN dequantization
        for (spec, arg) in man.params.iter().zip(&args).skip(1) {
            let base = spec.name.strip_suffix(".w").unwrap();
            let want = qm.get(base).unwrap().dequantize();
            match arg {
                HostArg::F32(v, dims) => {
                    assert_eq!(dims, &spec.dims);
                    assert_eq!(v, &want.data, "param {}", spec.name);
                }
                _ => panic!("expected f32 param"),
            }
        }
    }

    #[test]
    fn lut_kernel_rejects_mixed_model() {
        let w = tiny_weights();
        let qm = mixed_model(&w);
        let man = Manifest::parse("artifact decode_flute\nparam lut f32 16,2\n").unwrap();
        let err = Backend::Flute { bits: 2 }
            .build_params(&man, &w, Some(&qm))
            .unwrap_err();
        assert!(
            format!("{err:#}").contains("mixed"),
            "error should point at the mixed model: {err:#}"
        );
    }
}
