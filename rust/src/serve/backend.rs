//! Weight backends: how model weights are represented on the decode
//! path. Each backend names a decode artifact family and knows how to
//! assemble the executable's parameter list from a (dense, quantized)
//! model pair — the rust side of Table 1's kernel comparison.

use super::planes::PlaneStore;
use crate::grids::Grid;
use crate::model::manifest::{DType, Manifest};
use crate::model::Weights;
use crate::quant::artifact::{LayerScheme, PlaneData, QuantArtifact};
use crate::quant::reader::ArtifactReader;
use crate::quant::{QuantData, QuantizedModel};
use crate::runtime::HostArg;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// Where a backend's quantized parameters come from: the in-memory
/// [`QuantizedModel`], a fully-loaded [`QuantArtifact`], or an
/// on-disk [`ArtifactReader`] — the lazy cold-start path, where each
/// layer's plane is pulled off disk with one checksummed ranged read
/// and dense weights decode STRAIGHT from the bit-packed planes
/// (`dequantize_from_packed` kernels, no unpacked code plane, no
/// re-quantization). All three flow through the same provisioning
/// pipeline: [`PlaneStore`] decodes each quantized layer ONCE, and
/// [`Backend::build_params_with`] assembles executables' params from
/// the store.
#[derive(Clone, Copy)]
pub enum QuantSource<'a> {
    Model(&'a QuantizedModel),
    Artifact(&'a QuantArtifact),
    Reader(&'a ArtifactReader),
}

impl<'a> QuantSource<'a> {
    fn is_empty(&self) -> bool {
        match self {
            QuantSource::Model(m) => m.layers.is_empty(),
            QuantSource::Artifact(a) => a.layers.is_empty(),
            QuantSource::Reader(r) => r.entries().is_empty(),
        }
    }

    /// Does the source carry a quantized layer named `base`? (Cheap:
    /// an index lookup — no plane read even for the reader.)
    pub(crate) fn covers(&self, base: &str) -> bool {
        match self {
            QuantSource::Model(m) => m.get(base).is_some(),
            QuantSource::Artifact(a) => a.get(base).is_some(),
            QuantSource::Reader(r) => r.entry(base).is_some(),
        }
    }

    fn shared_lut_grid(&self) -> Option<Arc<Grid>> {
        match self {
            QuantSource::Model(m) => m.shared_lut_grid(),
            QuantSource::Artifact(a) => a.shared_lut_grid(),
            QuantSource::Reader(r) => r.shared_lut_grid(),
        }
    }

    /// Dense weights of layer `base`. Model sources run the blocked
    /// decode over the unpacked plane; artifact sources decode from
    /// the packed words directly; reader sources pay one ranged
    /// (checksummed) plane read first. Errors if the source does not
    /// cover `base` (check [`QuantSource::covers`] first) or the
    /// ranged read fails.
    pub(crate) fn dense_weight(&self, base: &str) -> Result<Tensor> {
        match self {
            QuantSource::Model(m) => Ok(lookup(Some(*m), base)?.dequantize()),
            QuantSource::Artifact(a) => Ok(lookup_scheme(a, base)?.dequantize()),
            QuantSource::Reader(r) => Ok(Self::reader_scheme(r, base)?.dequantize()),
        }
    }

    /// The layer's full scheme out of a lazy source, through the
    /// reader's per-layer memo: the FIRST accessor touching a layer
    /// pays the ranged (checksummed) read + decode, every later one —
    /// and an engine construction makes several per layer (codes,
    /// scales, signs…) — hits the cache with no disk I/O.
    /// `layer_scheme` already distinguishes a genuinely-missing layer
    /// from a checksum/I/O failure — no extra context here, it would
    /// mislabel corruption as absence.
    fn reader_scheme(r: &ArtifactReader, base: &str) -> Result<Arc<LayerScheme>> {
        r.layer_scheme(base)
    }

    /// The layer's code plane widened to the i32 the executables take.
    /// Model sources map straight off the borrowed plane (no u32
    /// clone); artifact/reader sources unpack once.
    fn codes_i32(&self, base: &str) -> Result<Vec<i32>> {
        let from_plane = |plane: &PlaneData| -> Vec<i32> {
            let packed = match plane {
                PlaneData::Lut { packed, .. } => packed,
                PlaneData::Uniform { packed, .. } => packed,
            };
            packed.unpack().into_iter().map(|c| c as i32).collect()
        };
        match self {
            QuantSource::Model(m) => {
                let ql = lookup(Some(*m), base)?;
                let codes: &[u32] = match &ql.data {
                    QuantData::Lut { codes, .. } => codes,
                    QuantData::Uniform { codes, .. } => codes,
                };
                Ok(codes.iter().map(|&c| c as i32).collect())
            }
            QuantSource::Artifact(a) => Ok(from_plane(&lookup_scheme(a, base)?.plane)),
            QuantSource::Reader(r) => Ok(from_plane(&Self::reader_scheme(r, base)?.plane)),
        }
    }

    fn lut_scales(&self, base: &str) -> Result<Vec<f32>> {
        let from_plane = |plane: &PlaneData| -> Result<Vec<f32>> {
            match plane {
                PlaneData::Lut { scales, .. } => Ok(scales.clone()),
                _ => bail!("{base}: not LUT data"),
            }
        };
        match self {
            QuantSource::Model(m) => match &lookup(Some(*m), base)?.data {
                QuantData::Lut { scales, .. } => Ok(scales.clone()),
                _ => bail!("{base}: not LUT data"),
            },
            QuantSource::Artifact(a) => from_plane(&lookup_scheme(a, base)?.plane),
            QuantSource::Reader(r) => from_plane(&Self::reader_scheme(r, base)?.plane),
        }
    }

    fn uniform_steps(&self, base: &str) -> Result<Vec<f32>> {
        let from_plane = |plane: &PlaneData| -> Result<Vec<f32>> {
            match plane {
                PlaneData::Uniform { steps, .. } => Ok(steps.clone()),
                _ => bail!("{base}: not uniform data"),
            }
        };
        match self {
            QuantSource::Model(m) => match &lookup(Some(*m), base)?.data {
                QuantData::Uniform { steps, .. } => Ok(steps.clone()),
                _ => bail!("{base}: not uniform data"),
            },
            QuantSource::Artifact(a) => from_plane(&lookup_scheme(a, base)?.plane),
            QuantSource::Reader(r) => from_plane(&Self::reader_scheme(r, base)?.plane),
        }
    }

    fn uniform_zeros(&self, base: &str) -> Result<Vec<f32>> {
        let from_plane = |plane: &PlaneData| -> Result<Vec<f32>> {
            match plane {
                PlaneData::Uniform { zeros, .. } => Ok(zeros.clone()),
                _ => bail!("{base}: not uniform data"),
            }
        };
        match self {
            QuantSource::Model(m) => match &lookup(Some(*m), base)?.data {
                QuantData::Uniform { zeros, .. } => Ok(zeros.clone()),
                _ => bail!("{base}: not uniform data"),
            },
            QuantSource::Artifact(a) => from_plane(&lookup_scheme(a, base)?.plane),
            QuantSource::Reader(r) => from_plane(&Self::reader_scheme(r, base)?.plane),
        }
    }

    fn signs(&self, base: &str) -> Result<Vec<f32>> {
        let from_plane = |plane: &PlaneData| -> Result<Vec<f32>> {
            match plane {
                PlaneData::Lut { signs: Some(s), .. } => Ok(s.clone()),
                _ => bail!("{base}: layer has no RHT signs"),
            }
        };
        match self {
            QuantSource::Model(m) => match &lookup(Some(*m), base)?.data {
                QuantData::Lut { signs: Some(s), .. } => Ok(s.clone()),
                _ => bail!("{base}: layer has no RHT signs"),
            },
            QuantSource::Artifact(a) => from_plane(&lookup_scheme(a, base)?.plane),
            QuantSource::Reader(r) => from_plane(&Self::reader_scheme(r, base)?.plane),
        }
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Backend {
    /// dense f32 GEMM (the FP16 baseline of Table 1)
    Dense,
    /// fused scale/zero uniform dequant (MARLIN stand-in), b=4
    Uniform4,
    /// unfused scalar LUT (NF4/bitsandbytes stand-in), n=16
    NfLut4,
    /// fused vector-LUT Pallas kernel + activation RHT (FLUTE/HIGGS)
    Flute { bits: u32 },
    /// mixed-precision model (§5 dynamic allocation): every layer
    /// carries its own grid/bits, served through the dense decode
    /// executable on per-layer dequantized weights (the LUT kernels
    /// take ONE global grid, which a mixed model does not have)
    Mixed,
}

impl Backend {
    pub fn label(&self) -> String {
        match self {
            Backend::Dense => "fp16".into(),
            Backend::Uniform4 => "marlin(uniform4)".into(),
            Backend::NfLut4 => "nf4".into(),
            Backend::Flute { bits } => format!("flute{bits}"),
            Backend::Mixed => "mixed".into(),
        }
    }

    /// The decode artifact name for (cfg, batch).
    pub fn decode_artifact(&self, cfg_name: &str, batch: usize) -> String {
        match self {
            Backend::Dense | Backend::Mixed => format!("decode_dense_{cfg_name}_b{batch}"),
            Backend::Uniform4 => format!("decode_uniform_b4_{cfg_name}_b{batch}"),
            Backend::NfLut4 => format!("decode_nf_n16_{cfg_name}_b{batch}"),
            Backend::Flute { bits } => {
                let n = 1usize << (2 * bits); // p=2 grids
                format!("decode_flute_p2_n{n}_rht_{cfg_name}_b{batch}")
            }
        }
    }

    /// Prefill always runs the dense artifact on (de)quantized weights —
    /// numerically identical to the backend's representation (App. G).
    pub fn prefill_artifact(&self, cfg_name: &str, batch: usize) -> String {
        format!("prefill_dense_{cfg_name}_b{batch}")
    }

    /// Assemble the decode executable's `param` arguments in manifest
    /// order from full-precision weights + the quantized model.
    pub fn build_params(
        &self,
        man: &Manifest,
        weights: &Weights,
        qmodel: Option<&QuantizedModel>,
    ) -> Result<Vec<HostArg>> {
        self.build_params_from(man, weights, qmodel.map(QuantSource::Model))
    }

    /// [`Backend::build_params`] generalized over the parameter source:
    /// an in-memory model, a loaded [`QuantArtifact`], or an on-disk
    /// [`ArtifactReader`] (serving cold start straight from packed
    /// planes). Builds a private [`PlaneStore`] for this one manifest;
    /// callers provisioning SEVERAL manifests from the same source
    /// (engine construction: decode + prefill) should build one store
    /// over all of them and call [`Backend::build_params_with`] so
    /// each layer decodes exactly once.
    pub fn build_params_from(
        &self,
        man: &Manifest,
        weights: &Weights,
        src: Option<QuantSource<'_>>,
    ) -> Result<Vec<HostArg>> {
        let store = match src {
            Some(s) => PlaneStore::build_for(s, &[man])?,
            None => PlaneStore::empty(),
        };
        self.build_params_with(man, weights, src, &store)
    }

    /// [`Backend::build_params_from`] drawing every dense `.w` plane
    /// from an already-decoded [`PlaneStore`] — the decode-once
    /// provisioning path. The store is the ONLY place layer decodes
    /// happen (it fans them out over the pool; see
    /// [`PlaneStore::build_for`]); this pass just assembles `HostArg`s
    /// in manifest order. A layer the store does not hold falls back
    /// to decoding from `src` directly (correct but paying an extra
    /// decode — only reachable with a store built for other
    /// manifests).
    pub fn build_params_with(
        &self,
        man: &Manifest,
        weights: &Weights,
        src: Option<QuantSource<'_>>,
        store: &PlaneStore,
    ) -> Result<Vec<HostArg>> {
        let mut out = Vec::with_capacity(man.params.len());
        for spec in man.params.iter() {
            let arg = if spec.name == "lut" {
                let src = src.context("lut param but no quantized model")?;
                if src.is_empty() {
                    bail!("empty quantized model");
                }
                // the decode executable bakes in ONE global grid: a
                // mixed-precision model (per-layer grids) would silently
                // decode every non-matching layer's codes against the
                // wrong LUT — reject it here instead
                let grid = src.shared_lut_grid().context(
                    "decode artifact expects a single shared LUT grid, but the \
                     quantized model is mixed-precision; serve it with \
                     Backend::Mixed (dense decode on per-layer dequantized \
                     weights) instead",
                )?;
                if grid.n * grid.p != spec.numel() {
                    bail!(
                        "grid {}x{} does not match lut param {:?}",
                        grid.n,
                        grid.p,
                        spec.dims
                    );
                }
                HostArg::F32(grid.points.clone(), spec.dims.clone())
            } else if let Some(base) = spec.name.strip_suffix(".w") {
                // dense linear weight: use dequantized values if we have
                // a quantized source — decoded ONCE in the shared
                // PlaneStore, which clones for every consuming manifest
                // but the last and MOVES the plane to the last (the
                // single-manifest wrapper path is zero-copy)
                let t = match store.claim(base) {
                    Some(t) => t,
                    None => match src {
                        Some(s) if s.covers(base) => s.dense_weight(base)?,
                        _ => weights.linear(base).context("missing linear")?.clone(),
                    },
                };
                if t.data.len() != spec.numel() {
                    bail!(
                        "{}: decoded {} values vs manifest {:?}",
                        spec.name,
                        t.data.len(),
                        spec.dims
                    );
                }
                HostArg::F32(t.data, spec.dims.clone())
            } else if let Some(base) = spec.name.strip_suffix(".codes") {
                let src = src.context("quantized param but no quantized model")?;
                let codes = src.codes_i32(base)?;
                if codes.len() != spec.numel() {
                    bail!("{}: codes len {} vs {:?}", spec.name, codes.len(), spec.dims);
                }
                HostArg::I32(codes, spec.dims.clone())
            } else if let Some(base) = spec.name.strip_suffix(".scales") {
                let src = src.context("quantized param but no quantized model")?;
                HostArg::F32(src.lut_scales(base)?, spec.dims.clone())
            } else if let Some(base) = spec.name.strip_suffix(".scale") {
                let src = src.context("quantized param but no quantized model")?;
                HostArg::F32(src.uniform_steps(base)?, spec.dims.clone())
            } else if let Some(base) = spec.name.strip_suffix(".zero") {
                let src = src.context("quantized param but no quantized model")?;
                HostArg::F32(src.uniform_zeros(base)?, spec.dims.clone())
            } else if let Some(base) = spec.name.strip_suffix(".signs") {
                let src = src.context("quantized param but no quantized model")?;
                HostArg::F32(src.signs(base)?, spec.dims.clone())
            } else {
                // embed / norms: full precision
                let t = weights
                    .get(&spec.name)
                    .with_context(|| format!("weights missing {}", spec.name))?;
                if spec.dtype != DType::F32 {
                    bail!("{}: expected f32", spec.name);
                }
                HostArg::F32(t.data.clone(), spec.dims.clone())
            };
            out.push(arg);
        }
        Ok(out)
    }
}

fn lookup<'a>(
    qmodel: Option<&'a QuantizedModel>,
    base: &str,
) -> Result<&'a crate::quant::QuantizedLayer> {
    qmodel
        .context("quantized param but no quantized model")?
        .get(base)
        .with_context(|| format!("quantized model missing layer {base}"))
}

fn lookup_scheme<'a>(
    artifact: &'a QuantArtifact,
    base: &str,
) -> Result<&'a crate::quant::artifact::LayerScheme> {
    artifact
        .get(base)
        .with_context(|| format!("quantized artifact missing layer {base}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::grids::registry::GridRegistry;
    use crate::grids::GridKind;
    use crate::quant::higgs::HiggsQuantizer;
    use crate::quant::Quantizer;

    #[test]
    fn artifact_names() {
        assert_eq!(Backend::Dense.decode_artifact("base", 4), "decode_dense_base_b4");
        assert_eq!(
            Backend::Flute { bits: 3 }.decode_artifact("base", 16),
            "decode_flute_p2_n64_rht_base_b16"
        );
        assert_eq!(
            Backend::Uniform4.decode_artifact("base", 1),
            "decode_uniform_b4_base_b1"
        );
        assert_eq!(Backend::NfLut4.decode_artifact("base", 1), "decode_nf_n16_base_b1");
        // mixed models are served through the dense decode executable
        assert_eq!(Backend::Mixed.decode_artifact("base", 1), "decode_dense_base_b1");
    }

    #[test]
    fn labels_distinct() {
        let all = [
            Backend::Dense,
            Backend::Uniform4,
            Backend::NfLut4,
            Backend::Flute { bits: 2 },
            Backend::Flute { bits: 4 },
            Backend::Mixed,
        ];
        let labels: std::collections::HashSet<String> =
            all.iter().map(|b| b.label()).collect();
        assert_eq!(labels.len(), all.len());
    }

    use crate::model::fixture;

    fn tiny_weights() -> Weights {
        fixture::tiny_weights(5)
    }

    /// Quantize the tiny model with ALTERNATING grids (a mixed model).
    fn mixed_model(w: &Weights) -> QuantizedModel {
        let reg = GridRegistry::new();
        let q2 = HiggsQuantizer::new(reg.get(GridKind::Higgs, 16, 2), 16, 1);
        let q4 = HiggsQuantizer::new(reg.get(GridKind::Higgs, 256, 2), 16, 1);
        let names = w.linear_names();
        let assignment: Vec<(String, &dyn Quantizer)> = names
            .iter()
            .enumerate()
            .map(|(i, n)| {
                let q: &dyn Quantizer = if i % 2 == 0 { &q2 } else { &q4 };
                (n.clone(), q)
            })
            .collect();
        QuantizedModel::quantize_mixed(w, &assignment)
    }

    #[test]
    fn mixed_backend_builds_dense_params_from_mixed_model() {
        let w = tiny_weights();
        let qm = mixed_model(&w);
        assert!(qm.shared_lut_grid().is_none(), "model should be mixed");
        // the dense/Mixed manifest: every param as the dense graph sees it
        let cfg = fixture::tiny_config();
        let mut text = String::from("artifact decode_dense_tiny_b1\n");
        text += &format!("param embed f32 {},{}\n", cfg.vocab, cfg.d_model);
        for (n, (k, m)) in cfg.linear_shapes() {
            text += &format!("param {n}.w f32 {k},{m}\n");
        }
        let man = Manifest::parse(&text).unwrap();
        let args = Backend::Mixed.build_params(&man, &w, Some(&qm)).unwrap();
        assert_eq!(args.len(), man.params.len());
        // each linear param is the layer's OWN dequantization
        for (spec, arg) in man.params.iter().zip(&args).skip(1) {
            let base = spec.name.strip_suffix(".w").unwrap();
            let want = qm.get(base).unwrap().dequantize();
            match arg {
                HostArg::F32(v, dims) => {
                    assert_eq!(dims, &spec.dims);
                    assert_eq!(v, &want.data, "param {}", spec.name);
                }
                _ => panic!("expected f32 param"),
            }
        }
    }

    #[test]
    fn artifact_source_builds_identical_params() {
        // serving cold start: params assembled straight from the
        // artifact's packed planes must be bit-identical to the
        // in-memory model's
        let w = tiny_weights();
        let qm = mixed_model(&w);
        let art = crate::quant::artifact::QuantArtifact::from_model("tiny", &qm);
        let cfg = fixture::tiny_config();
        let mut text = String::from("artifact decode_dense_tiny_b1\n");
        text += &format!("param embed f32 {},{}\n", cfg.vocab, cfg.d_model);
        for (n, (k, m)) in cfg.linear_shapes() {
            text += &format!("param {n}.w f32 {k},{m}\n");
        }
        let man = Manifest::parse(&text).unwrap();
        art.validate_against(&man).unwrap();
        let from_model = Backend::Mixed.build_params(&man, &w, Some(&qm)).unwrap();
        let from_art = Backend::Mixed
            .build_params_from(&man, &w, Some(QuantSource::Artifact(&art)))
            .unwrap();
        assert_eq!(from_model.len(), from_art.len());
        for ((a, b), spec) in from_model.iter().zip(&from_art).zip(&man.params) {
            match (a, b) {
                (HostArg::F32(x, dx), HostArg::F32(y, dy)) => {
                    assert_eq!(dx, dy, "param {}", spec.name);
                    let xb: Vec<u32> = x.iter().map(|v| v.to_bits()).collect();
                    let yb: Vec<u32> = y.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(xb, yb, "param {}", spec.name);
                }
                _ => panic!("expected f32 params"),
            }
        }
    }

    #[test]
    fn lut_kernel_rejects_mixed_model() {
        let w = tiny_weights();
        let qm = mixed_model(&w);
        let man = Manifest::parse("artifact decode_flute\nparam lut f32 16,2\n").unwrap();
        let err = Backend::Flute { bits: 2 }
            .build_params(&man, &w, Some(&qm))
            .unwrap_err();
        assert!(
            format!("{err:#}").contains("mixed"),
            "error should point at the mixed model: {err:#}"
        );
    }
}
