//! Weight backends: how model weights are represented on the decode
//! path. Each backend names a decode artifact family and knows how to
//! assemble the executable's parameter list from a (dense, quantized)
//! model pair — the rust side of Table 1's kernel comparison.

use crate::model::manifest::{DType, Manifest};
use crate::model::Weights;
use crate::quant::{QuantData, QuantizedModel};
use crate::runtime::HostArg;
use anyhow::{bail, Context, Result};

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Backend {
    /// dense f32 GEMM (the FP16 baseline of Table 1)
    Dense,
    /// fused scale/zero uniform dequant (MARLIN stand-in), b=4
    Uniform4,
    /// unfused scalar LUT (NF4/bitsandbytes stand-in), n=16
    NfLut4,
    /// fused vector-LUT Pallas kernel + activation RHT (FLUTE/HIGGS)
    Flute { bits: u32 },
}

impl Backend {
    pub fn label(&self) -> String {
        match self {
            Backend::Dense => "fp16".into(),
            Backend::Uniform4 => "marlin(uniform4)".into(),
            Backend::NfLut4 => "nf4".into(),
            Backend::Flute { bits } => format!("flute{bits}"),
        }
    }

    /// The decode artifact name for (cfg, batch).
    pub fn decode_artifact(&self, cfg_name: &str, batch: usize) -> String {
        match self {
            Backend::Dense => format!("decode_dense_{cfg_name}_b{batch}"),
            Backend::Uniform4 => format!("decode_uniform_b4_{cfg_name}_b{batch}"),
            Backend::NfLut4 => format!("decode_nf_n16_{cfg_name}_b{batch}"),
            Backend::Flute { bits } => {
                let n = 1usize << (2 * bits); // p=2 grids
                format!("decode_flute_p2_n{n}_rht_{cfg_name}_b{batch}")
            }
        }
    }

    /// Prefill always runs the dense artifact on (de)quantized weights —
    /// numerically identical to the backend's representation (App. G).
    pub fn prefill_artifact(&self, cfg_name: &str, batch: usize) -> String {
        format!("prefill_dense_{cfg_name}_b{batch}")
    }

    /// Assemble the decode executable's `param` arguments in manifest
    /// order from full-precision weights + the quantized model.
    pub fn build_params(
        &self,
        man: &Manifest,
        weights: &Weights,
        qmodel: Option<&QuantizedModel>,
    ) -> Result<Vec<HostArg>> {
        let mut out = Vec::with_capacity(man.params.len());
        for spec in &man.params {
            let arg = if spec.name == "lut" {
                let qm = qmodel.context("lut param but no quantized model")?;
                let grid = match &qm.layers.first().context("empty qmodel")?.data {
                    QuantData::Lut { grid, .. } => grid.clone(),
                    _ => bail!("lut param but first layer is not LUT-quantized"),
                };
                if grid.n * grid.p != spec.numel() {
                    bail!(
                        "grid {}x{} does not match lut param {:?}",
                        grid.n,
                        grid.p,
                        spec.dims
                    );
                }
                HostArg::F32(grid.points.clone(), spec.dims.clone())
            } else if let Some(base) = spec.name.strip_suffix(".w") {
                // dense linear weight: use dequantized values if we have
                // a quantized model (keeps dense-backend comparisons
                // honest), else original
                let t = match qmodel.and_then(|qm| qm.get(base)) {
                    Some(ql) => ql.dequantize(),
                    None => weights.linear(base).context("missing linear")?.clone(),
                };
                HostArg::F32(t.data, spec.dims.clone())
            } else if let Some(base) = spec.name.strip_suffix(".codes") {
                let ql = lookup(qmodel, base)?;
                let codes: &[u32] = match &ql.data {
                    QuantData::Lut { codes, .. } => codes,
                    QuantData::Uniform { codes, .. } => codes,
                };
                if codes.len() != spec.numel() {
                    bail!("{}: codes len {} vs {:?}", spec.name, codes.len(), spec.dims);
                }
                HostArg::I32(codes.iter().map(|&c| c as i32).collect(), spec.dims.clone())
            } else if let Some(base) = spec.name.strip_suffix(".scales") {
                let ql = lookup(qmodel, base)?;
                match &ql.data {
                    QuantData::Lut { scales, .. } => {
                        HostArg::F32(scales.clone(), spec.dims.clone())
                    }
                    _ => bail!("{}: not LUT data", spec.name),
                }
            } else if let Some(base) = spec.name.strip_suffix(".scale") {
                let ql = lookup(qmodel, base)?;
                match &ql.data {
                    QuantData::Uniform { steps, .. } => {
                        HostArg::F32(steps.clone(), spec.dims.clone())
                    }
                    _ => bail!("{}: not uniform data", spec.name),
                }
            } else if let Some(base) = spec.name.strip_suffix(".zero") {
                let ql = lookup(qmodel, base)?;
                match &ql.data {
                    QuantData::Uniform { zeros, .. } => {
                        HostArg::F32(zeros.clone(), spec.dims.clone())
                    }
                    _ => bail!("{}: not uniform data", spec.name),
                }
            } else if let Some(base) = spec.name.strip_suffix(".signs") {
                let ql = lookup(qmodel, base)?;
                match &ql.data {
                    QuantData::Lut { signs: Some(s), .. } => {
                        HostArg::F32(s.clone(), spec.dims.clone())
                    }
                    _ => bail!("{}: layer has no RHT signs", spec.name),
                }
            } else {
                // embed / norms: full precision
                let t = weights
                    .get(&spec.name)
                    .with_context(|| format!("weights missing {}", spec.name))?;
                if spec.dtype != DType::F32 {
                    bail!("{}: expected f32", spec.name);
                }
                HostArg::F32(t.data.clone(), spec.dims.clone())
            };
            out.push(arg);
        }
        Ok(out)
    }
}

fn lookup<'a>(
    qmodel: Option<&'a QuantizedModel>,
    base: &str,
) -> Result<&'a crate::quant::QuantizedLayer> {
    qmodel
        .context("quantized param but no quantized model")?
        .get(base)
        .with_context(|| format!("quantized model missing layer {base}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names() {
        assert_eq!(Backend::Dense.decode_artifact("base", 4), "decode_dense_base_b4");
        assert_eq!(
            Backend::Flute { bits: 3 }.decode_artifact("base", 16),
            "decode_flute_p2_n64_rht_base_b16"
        );
        assert_eq!(
            Backend::Uniform4.decode_artifact("base", 1),
            "decode_uniform_b4_base_b1"
        );
        assert_eq!(Backend::NfLut4.decode_artifact("base", 1), "decode_nf_n16_base_b1");
    }

    #[test]
    fn labels_distinct() {
        let all = [
            Backend::Dense,
            Backend::Uniform4,
            Backend::NfLut4,
            Backend::Flute { bits: 2 },
            Backend::Flute { bits: 4 },
        ];
        let labels: std::collections::HashSet<String> =
            all.iter().map(|b| b.label()).collect();
        assert_eq!(labels.len(), all.len());
    }
}
