//! Request-lifecycle tracing for the serving daemon (PERF.md §13):
//! every request carries a [`RequestSpan`] recording its
//! enqueue → admit → first-token → per-step decode → complete
//! timestamps, all on the ONE [`Clock`](super::trace::Clock) the
//! daemon runs on — so virtual-clock tests get exact, sleep-free span
//! assertions and wall-clock runs get real latencies from the same
//! code path.
//!
//! Spans are ring-buffered ([`SpanRing`], capacity `HIGGS_TRACE_RING`)
//! so a long-lived daemon holds bounded memory, and dumpable as JSONL
//! (`serve-daemon --trace-out PATH`) for offline analysis.
//! [`phase_stats`] reduces completed spans to the per-phase latency
//! percentiles surfaced in `ServeMetrics::phases`.
//!
//! Distinct from `serve/trace.rs`, which models the WORKLOAD (arrival
//! traces + the clock); this module traces the LIFECYCLE of each
//! request inside the daemon.
//!
//! This module is under the `wall-clock` audit rule: timestamps only
//! ever arrive as `now_ms` arguments read off the daemon's clock.

use crate::serve::metrics::PhaseStats;
use crate::util::stats::percentile;
use std::collections::VecDeque;
use std::fmt::Write as _;

/// Terminal state of a request's lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanOutcome {
    /// still in flight (only ever observed on live spans)
    Pending,
    /// generated its tokens and streamed `Done`
    Complete,
    /// deadline expired before admission → typed timeout `Error`
    Timeout,
    /// invalid request (empty prompt, zero `max_new`) → `Error`
    Rejected,
    /// bounced with `Busy` (queue full or draining)
    Busy,
    /// engine failure → `Error{Internal}`
    Error,
}

impl SpanOutcome {
    pub fn label(self) -> &'static str {
        match self {
            SpanOutcome::Pending => "pending",
            SpanOutcome::Complete => "complete",
            SpanOutcome::Timeout => "timeout",
            SpanOutcome::Rejected => "rejected",
            SpanOutcome::Busy => "busy",
            SpanOutcome::Error => "error",
        }
    }
}

/// One request's lifecycle timestamps, all in clock-milliseconds on
/// the daemon's `Clock`. Invariant (asserted by `prop_daemon`):
/// `enqueue_ms ≤ admit_ms ≤ first_token_ms ≤ complete_ms` for every
/// completed span.
#[derive(Clone, Debug)]
pub struct RequestSpan {
    /// the CLIENT's request id (what `Token`/`Done` replies carry)
    pub id: u64,
    /// which connection submitted it (daemon-assigned, 0 for direct)
    pub client: u64,
    pub prompt_len: usize,
    pub enqueue_ms: f64,
    /// set when the pipeline admits the request into a slot
    pub admit_ms: Option<f64>,
    /// set when token index 0 is produced (end of prefill)
    pub first_token_ms: Option<f64>,
    /// timestamp of every produced token, in order
    pub step_ms: Vec<f64>,
    pub complete_ms: Option<f64>,
    pub outcome: SpanOutcome,
    pub tokens: usize,
}

impl RequestSpan {
    pub fn start(id: u64, client: u64, prompt_len: usize, now_ms: f64) -> RequestSpan {
        RequestSpan {
            id,
            client,
            prompt_len,
            enqueue_ms: now_ms,
            admit_ms: None,
            first_token_ms: None,
            step_ms: Vec::new(),
            complete_ms: None,
            outcome: SpanOutcome::Pending,
            tokens: 0,
        }
    }

    /// Record one produced token. Index 0 doubles as the admit /
    /// end-of-prefill mark: the pipeline produces the first token as
    /// part of admission, so they share a timestamp.
    pub fn note_token(&mut self, index: usize, now_ms: f64) {
        if index == 0 {
            self.admit_ms = Some(now_ms);
            self.first_token_ms = Some(now_ms);
        }
        self.step_ms.push(now_ms);
        self.tokens = self.tokens.max(index + 1);
    }

    /// Close the span with its terminal outcome.
    pub fn finish(&mut self, outcome: SpanOutcome, now_ms: f64) {
        self.outcome = outcome;
        self.complete_ms = Some(now_ms);
    }

    /// One JSONL record (hand-rolled: the crate carries no serde).
    pub fn to_json(&self) -> String {
        let opt = |v: Option<f64>| match v {
            Some(x) => format!("{x:.3}"),
            None => "null".to_string(),
        };
        let mut steps = String::from("[");
        for (i, s) in self.step_ms.iter().enumerate() {
            if i > 0 {
                steps.push(',');
            }
            let _ = write!(steps, "{s:.3}");
        }
        steps.push(']');
        format!(
            "{{\"id\":{},\"client\":{},\"prompt_len\":{},\"enqueue_ms\":{:.3},\
             \"admit_ms\":{},\"first_token_ms\":{},\"complete_ms\":{},\
             \"tokens\":{},\"outcome\":\"{}\",\"step_ms\":{}}}",
            self.id,
            self.client,
            self.prompt_len,
            self.enqueue_ms,
            opt(self.admit_ms),
            opt(self.first_token_ms),
            opt(self.complete_ms),
            self.tokens,
            self.outcome.label(),
            steps,
        )
    }
}

/// Bounded span history: the daemon pushes every finished span; once
/// `cap` is exceeded the oldest drops (`total` keeps counting), so a
/// week-long daemon holds bounded memory while `--trace-out` still
/// dumps the most recent window.
#[derive(Debug)]
pub struct SpanRing {
    cap: usize,
    spans: VecDeque<RequestSpan>,
    total: u64,
}

impl SpanRing {
    pub fn new(cap: usize) -> SpanRing {
        SpanRing { cap: cap.max(1), spans: VecDeque::new(), total: 0 }
    }

    /// Ring capacity from the `HIGGS_TRACE_RING` knob (default 1024).
    pub fn default_capacity() -> usize {
        crate::util::env_usize("HIGGS_TRACE_RING", 1024)
    }

    pub fn push(&mut self, span: RequestSpan) {
        if self.spans.len() == self.cap {
            self.spans.pop_front();
        }
        self.spans.push_back(span);
        self.total += 1;
    }

    pub fn len(&self) -> usize {
        self.spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// Spans ever pushed, including ones the ring has since dropped.
    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn iter(&self) -> impl Iterator<Item = &RequestSpan> {
        self.spans.iter()
    }

    /// All retained spans as JSONL, oldest first.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for s in &self.spans {
            out += &s.to_json();
            out.push('\n');
        }
        out
    }

    pub fn write_jsonl(&self, path: &std::path::Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_jsonl())
            .map_err(|e| anyhow::anyhow!("write trace {}: {e}", path.display()))
    }
}

/// Reduce the ring's COMPLETED spans to per-phase percentiles:
/// queue (enqueue→admit), prefill (admit→first token — 0 by
/// construction today since admission produces the first token, kept
/// as its own row for when prefill decouples), decode (first
/// token→complete), total (enqueue→complete).
pub fn phase_stats(ring: &SpanRing) -> Vec<PhaseStats> {
    let mut queue = Vec::new();
    let mut prefill = Vec::new();
    let mut decode = Vec::new();
    let mut total = Vec::new();
    for s in ring.iter() {
        if s.outcome != SpanOutcome::Complete {
            continue;
        }
        let (Some(admit), Some(first), Some(done)) =
            (s.admit_ms, s.first_token_ms, s.complete_ms)
        else {
            continue;
        };
        queue.push(admit - s.enqueue_ms);
        prefill.push(first - admit);
        decode.push(done - first);
        total.push(done - s.enqueue_ms);
    }
    let row = |phase: &'static str, v: &[f64]| PhaseStats {
        phase,
        count: v.len(),
        p50_ms: if v.is_empty() { 0.0 } else { percentile(v, 50.0) },
        p95_ms: if v.is_empty() { 0.0 } else { percentile(v, 95.0) },
        p99_ms: if v.is_empty() { 0.0 } else { percentile(v, 99.0) },
        max_ms: v.iter().copied().fold(0.0, f64::max),
    };
    if total.is_empty() {
        return Vec::new();
    }
    vec![
        row("queue", &queue),
        row("prefill", &prefill),
        row("decode", &decode),
        row("total", &total),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn completed(id: u64, t0: f64) -> RequestSpan {
        let mut s = RequestSpan::start(id, 1, 3, t0);
        s.note_token(0, t0 + 2.0);
        s.note_token(1, t0 + 3.0);
        s.note_token(2, t0 + 4.0);
        s.finish(SpanOutcome::Complete, t0 + 4.0);
        s
    }

    #[test]
    fn phase_ordering_and_token_marks() {
        let s = completed(7, 10.0);
        assert_eq!(s.admit_ms, Some(12.0));
        assert_eq!(s.first_token_ms, Some(12.0));
        assert_eq!(s.complete_ms, Some(14.0));
        assert_eq!(s.tokens, 3);
        assert!(s.enqueue_ms <= s.admit_ms.unwrap());
        assert!(s.admit_ms.unwrap() <= s.first_token_ms.unwrap());
        assert!(s.first_token_ms.unwrap() <= s.complete_ms.unwrap());
        assert_eq!(s.step_ms, vec![12.0, 13.0, 14.0]);
    }

    #[test]
    fn ring_wraps_and_counts_total() {
        let mut ring = SpanRing::new(3);
        for i in 0..5 {
            ring.push(completed(i, i as f64));
        }
        assert_eq!(ring.len(), 3);
        assert_eq!(ring.total(), 5);
        let ids: Vec<u64> = ring.iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![2, 3, 4]);
        // capacity floors at 1
        let mut tiny = SpanRing::new(0);
        tiny.push(completed(9, 0.0));
        tiny.push(completed(10, 1.0));
        assert_eq!(tiny.len(), 1);
        assert_eq!(tiny.iter().next().map(|s| s.id), Some(10));
    }

    #[test]
    fn jsonl_shape() {
        let mut ring = SpanRing::new(8);
        ring.push(completed(1, 0.0));
        let mut open = RequestSpan::start(2, 3, 1, 5.0);
        open.finish(SpanOutcome::Busy, 5.0);
        ring.push(open);
        let jsonl = ring.to_jsonl();
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("\"id\":1"));
        assert!(lines[0].contains("\"outcome\":\"complete\""));
        assert!(lines[0].contains("\"step_ms\":[2.000,3.000,4.000]"));
        assert!(lines[1].contains("\"admit_ms\":null"));
        assert!(lines[1].contains("\"outcome\":\"busy\""));
        for l in &lines {
            assert!(l.starts_with('{') && l.ends_with('}'));
        }
    }

    #[test]
    fn phase_stats_only_counts_completions() {
        let mut ring = SpanRing::new(16);
        assert!(phase_stats(&ring).is_empty());
        for i in 0..4 {
            ring.push(completed(i, 10.0 * i as f64));
        }
        let mut bounced = RequestSpan::start(99, 0, 1, 0.0);
        bounced.finish(SpanOutcome::Busy, 0.0);
        ring.push(bounced);
        let phases = phase_stats(&ring);
        assert_eq!(phases.len(), 4);
        let names: Vec<&str> = phases.iter().map(|p| p.phase).collect();
        assert_eq!(names, vec!["queue", "prefill", "decode", "total"]);
        for p in &phases {
            assert_eq!(p.count, 4, "bounced span leaked into phase {}", p.phase);
            assert!(p.p50_ms <= p.p95_ms && p.p95_ms <= p.p99_ms && p.p99_ms <= p.max_ms);
        }
        // queue = 2ms, decode = 2ms, total = 4ms for every span
        assert!((phases[0].p50_ms - 2.0).abs() < 1e-9);
        assert!((phases[1].p50_ms - 0.0).abs() < 1e-9);
        assert!((phases[2].p50_ms - 2.0).abs() < 1e-9);
        assert!((phases[3].p50_ms - 4.0).abs() < 1e-9);
    }
}
