//! Dynamic batcher: groups incoming requests into admission batches
//! under a (max size, deadline) policy — the vLLM-style front end of
//! the router. Pure logic (no XLA, no internal clock reads: callers
//! pass [`crate::serve::trace::Clock`] readings in), so it is
//! exhaustively testable and works identically under virtual replay.
//!
//! Requests are stamped at `push` ([`QueuedRequest`]) and carry that
//! submission timestamp through the engine, so end-to-end latency
//! includes time spent waiting here — not just time after admission.

use super::trace::{QueuedRequest, Request};
use std::collections::VecDeque;
use std::time::Duration;

#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// maximum requests to release at once (≤ engine batch)
    pub max_batch: usize,
    /// maximum time the oldest request may wait before release
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 16, max_wait: Duration::from_millis(5) }
    }
}

pub struct Batcher {
    cfg: BatcherConfig,
    pending: VecDeque<QueuedRequest>,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        Batcher { cfg, pending: VecDeque::new() }
    }

    /// Enqueue a request, stamped with the caller's clock reading.
    pub fn push(&mut self, req: Request, now_ms: f64) {
        self.pending.push_back(QueuedRequest::at(req, now_ms));
    }

    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Release a batch if the policy says so: either `max_batch`
    /// requests are waiting, or the oldest has exceeded `max_wait`, or
    /// `force` (engine idle) is set. Released requests keep their
    /// original submission timestamps.
    pub fn poll(&mut self, now_ms: f64, force: bool) -> Vec<QueuedRequest> {
        let wait_ms = self.cfg.max_wait.as_secs_f64() * 1e3;
        let due = self
            .pending
            .front()
            .map(|q| now_ms - q.enqueued_ms >= wait_ms)
            .unwrap_or(false);
        if self.pending.is_empty() || (!due && !force && self.pending.len() < self.cfg.max_batch)
        {
            return Vec::new();
        }
        let n = self.pending.len().min(self.cfg.max_batch);
        self.pending.drain(..n).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;

    fn req(id: u64) -> Request {
        Request { id, prompt: vec![1, 2], max_new: 4, arrival_ms: 0 }
    }

    #[test]
    fn releases_when_full() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 3,
            max_wait: Duration::from_secs(10),
        });
        b.push(req(0), 0.0);
        b.push(req(1), 0.0);
        assert!(b.poll(0.0, false).is_empty());
        b.push(req(2), 0.0);
        let out = b.poll(0.0, false);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].req.id, 0);
    }

    #[test]
    fn releases_on_deadline() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 100,
            max_wait: Duration::from_millis(2),
        });
        b.push(req(0), 0.0);
        assert!(b.poll(1.0, false).is_empty());
        let out = b.poll(2.0, false);
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn force_flushes() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 100,
            max_wait: Duration::from_secs(100),
        });
        b.push(req(0), 0.0);
        assert_eq!(b.poll(0.0, true).len(), 1);
        assert!(b.poll(0.0, true).is_empty());
    }

    #[test]
    fn submission_timestamp_survives_release() {
        let mut b = Batcher::new(BatcherConfig::default());
        b.push(req(0), 3.5);
        let out = b.poll(1_000.0, true);
        assert_eq!(out.len(), 1);
        // the released request still carries its push-time stamp
        assert_eq!(out[0].enqueued_ms, 3.5);
    }

    #[test]
    fn fifo_order_preserved() {
        forall("batcher fifo", 30, |g| {
            let n = g.usize_in(1, 40);
            let cap = g.usize_in(1, 8);
            let mut b = Batcher::new(BatcherConfig {
                max_batch: cap,
                max_wait: Duration::from_secs(100),
            });
            for i in 0..n {
                b.push(req(i as u64), i as f64);
            }
            let mut seen = Vec::new();
            loop {
                let out = b.poll(n as f64, true);
                if out.is_empty() {
                    break;
                }
                assert!(out.len() <= cap);
                seen.extend(out.iter().map(|q| q.req.id));
            }
            assert_eq!(seen, (0..n as u64).collect::<Vec<_>>());
        });
    }
}
