//! Training driver: Adam in rust stepping the AOT `grad_<cfg>` graph.
//!
//! The paper needs a *converged* model (Assumption 1: weights at a local
//! minimum) — we train the transformer from scratch on the synthetic
//! corpus, which is what makes the linearity-theorem experiments
//! meaningful on this testbed.

use crate::config::ModelConfig;
use crate::data::{Corpus, Split};
use crate::model::Weights;
use crate::runtime::{dense_args, Engine, HostArg};
use crate::tensor::Tensor;
use anyhow::{Context, Result};

pub struct AdamState {
    m: Vec<Tensor>,
    v: Vec<Tensor>,
    t: u64,
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl AdamState {
    pub fn new(weights: &Weights, lr: f32) -> Self {
        AdamState {
            m: weights.tensors.iter().map(|t| Tensor::zeros(&t.dims)).collect(),
            v: weights.tensors.iter().map(|t| Tensor::zeros(&t.dims)).collect(),
            t: 0,
            lr,
            beta1: 0.9,
            beta2: 0.95,
            eps: 1e-8,
            weight_decay: 0.01,
        }
    }

    /// One AdamW step; grads are in the same order as weights.tensors.
    pub fn step(&mut self, weights: &mut Weights, grads: &[Vec<f32>], lr_scale: f32) {
        assert_eq!(grads.len(), weights.tensors.len());
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        let lr = self.lr * lr_scale;
        for (i, g) in grads.iter().enumerate() {
            let w = &mut weights.tensors[i];
            let m = &mut self.m[i];
            let v = &mut self.v[i];
            debug_assert_eq!(g.len(), w.data.len());
            for j in 0..g.len() {
                let gj = g[j];
                m.data[j] = self.beta1 * m.data[j] + (1.0 - self.beta1) * gj;
                v.data[j] = self.beta2 * v.data[j] + (1.0 - self.beta2) * gj * gj;
                let mhat = m.data[j] / bc1;
                let vhat = v.data[j] / bc2;
                w.data[j] -=
                    lr * (mhat / (vhat.sqrt() + self.eps) + self.weight_decay * w.data[j]);
            }
        }
    }
}

pub struct TrainReport {
    pub steps: u64,
    pub losses: Vec<(u64, f32)>,
    pub final_loss: f32,
    pub tokens_seen: u64,
}

pub struct Trainer<'a> {
    pub engine: &'a Engine,
    pub cfg: ModelConfig,
    pub batch: usize,
    pub corpus: Corpus,
}

impl<'a> Trainer<'a> {
    pub fn new(engine: &'a Engine, cfg: ModelConfig) -> Self {
        let corpus = Corpus::new(cfg.vocab, cfg.seq, 0xC0_1155);
        Trainer { engine, cfg, batch: 8, corpus }
    }

    /// Run `steps` AdamW steps; logs the loss curve.
    pub fn train(
        &self,
        weights: &mut Weights,
        steps: u64,
        lr: f32,
        log_every: u64,
    ) -> Result<TrainReport> {
        let artifact = format!("grad_{}", self.cfg.name);
        let exe = self.engine.load(&artifact).with_context(|| artifact.clone())?;
        let mut adam = AdamState::new(weights, lr);
        let mut losses = Vec::new();
        let mut final_loss = f32::NAN;
        let warmup = (steps / 20).max(1);
        for step in 0..steps {
            let toks = self.corpus.batch(Split::Train, (step as usize) * self.batch, self.batch);
            let args = dense_args(
                &exe.manifest,
                vec![HostArg::I32(toks, vec![self.batch, self.cfg.seq])],
                weights,
            )?;
            let outs = self.engine.run(&exe, &args)?;
            let loss = outs[0].data[0];
            final_loss = loss;
            // cosine schedule with linear warmup
            let lr_scale = if step < warmup {
                (step + 1) as f32 / warmup as f32
            } else {
                let p = (step - warmup) as f32 / (steps - warmup).max(1) as f32;
                0.5 * (1.0 + (std::f32::consts::PI * p).cos()).max(0.05)
            };
            let grads: Vec<Vec<f32>> =
                outs[1..].iter().map(|o| o.data.clone()).collect();
            adam.step(weights, &grads, lr_scale);
            if step % log_every == 0 || step + 1 == steps {
                log::info!("step {step}: loss {loss:.4}");
                eprintln!("  train step {step:>5}: loss {loss:.4} (lr x{lr_scale:.2})");
                losses.push((step, loss));
            }
        }
        Ok(TrainReport {
            steps,
            losses,
            final_loss,
            tokens_seen: steps * (self.batch * self.cfg.seq) as u64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Manifest;

    fn have_artifacts() -> bool {
        crate::artifacts_dir().join("grad_tiny.hlo.txt").exists()
    }

    #[test]
    fn adam_reduces_quadratic() {
        // sanity: Adam on f(w) = ||w||² converges toward 0
        let cfg = crate::config::ModelConfig {
            name: "t".into(),
            vocab: 8,
            d_model: 4,
            n_layers: 1,
            n_heads: 1,
            d_ff: 4,
            seq: 8,
            group: 4,
        };
        let man = Manifest::parse("artifact x\nparam w f32 4,4\n").unwrap();
        let mut w = Weights::from_manifest(cfg, &man, Some(1)).unwrap();
        let mut adam = AdamState::new(&w, 0.05);
        adam.weight_decay = 0.0;
        let n0 = w.tensors[0].norm();
        for _ in 0..200 {
            let g: Vec<f32> = w.tensors[0].data.iter().map(|&x| 2.0 * x).collect();
            adam.step(&mut w, &[g], 1.0);
        }
        let n1 = w.tensors[0].norm();
        assert!(n1 < 0.1 * n0, "{n0} -> {n1}");
    }

    #[test]
    fn short_training_reduces_loss() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let eng = Engine::new().unwrap();
        let cfg = ModelConfig::load_named(eng.artifacts(), "tiny").unwrap();
        let exe = eng.load("grad_tiny").unwrap();
        let mut w = Weights::from_manifest(cfg.clone(), &exe.manifest, Some(7)).unwrap();
        let tr = Trainer::new(&eng, cfg);
        let report = tr.train(&mut w, 80, 3e-3, 20).unwrap();
        let first = report.losses.first().unwrap().1;
        assert!(
            report.final_loss < first - 0.1,
            "loss did not fall: {first} -> {}",
            report.final_loss
        );
    }
}
