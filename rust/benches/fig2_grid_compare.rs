//! Bench: regenerate paper Figure 2 — NF vs AF vs HIGGS(p) at ~3.25
//! bits (PPL on the trained model + grid-level MSE).

use higgs::experiments::{figures, ExpContext};

fn main() {
    let cfg = std::env::var("HIGGS_BENCH_CFG").unwrap_or_else(|_| "base".into());
    let ctx = match ExpContext::load(&cfg) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("fig2: skipping ({e:#})");
            return;
        }
    };
    let t0 = std::time::Instant::now();
    match figures::fig2_grid_compare(&ctx) {
        Ok(table) => {
            print!("{}", table.render());
            eprintln!("fig2 completed in {:.1}s", t0.elapsed().as_secs_f64());
        }
        Err(e) => eprintln!("fig2 failed: {e:#}"),
    }
}
