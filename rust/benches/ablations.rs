//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!  A. grid construction: CLVQ vs quantile init vs uniform (Gaussian MSE)
//!  B. rotation ablation: HIGGS vs same grid without RHT on heavy tails
//!  C. outlier handling: RHT (HIGGS) vs fp side-band (SpQR-lite) vs none
//!  D. scale group size: error vs bits trade-off of g ∈ {16..256}
//!  E. allocation solver: DP vs greedy vs Lagrange quality + runtime
//!  F. DP budget discretization granularity

use higgs::alloc::{solve_dp, solve_greedy, solve_lagrange, ErrorDb, GridChoice};
use higgs::grids::registry::{effective_bits, GridRegistry};
use higgs::grids::{gaussian_mse_of_1d, GridKind};
use higgs::linearity::calibrate::{CalibMetric, LayerAlphas};
use higgs::quant::higgs::HiggsQuantizer;
use higgs::quant::lut::LutQuantizer;
use higgs::quant::outlier::OutlierQuantizer;
use higgs::quant::rtn::RtnQuantizer;
use higgs::quant::Quantizer;
use higgs::report::Table;
use higgs::tensor::Tensor;
use higgs::util::bench::BenchRunner;
use higgs::util::prng::Rng;
use higgs::util::stats::norm_ppf;

fn heavy_tail_layer(k: usize, n: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let data: Vec<f32> = (0..k * n)
        .map(|_| {
            let z = rng.normal_f32();
            if rng.coin(0.01) {
                z * 15.0
            } else {
                z
            }
        })
        .collect();
    Tensor::from_vec(&[k, n], data)
}

fn main() {
    let reg = GridRegistry::new();

    // ---- A: grid construction quality ----
    let mut t = Table::new(
        "Ablation A: 1-D grid construction (Gaussian MSE, n=16)",
        &["constructor", "mse"],
    );
    let quantiles: Vec<f32> =
        (0..16).map(|i| norm_ppf((i as f64 + 0.5) / 16.0) as f32).collect();
    t.row(vec!["quantile init (NF)".into(), format!("{:.5}", gaussian_mse_of_1d(&quantiles))]);
    t.row(vec![
        "optimal uniform (CH)".into(),
        format!("{:.5}", reg.get(GridKind::Uniform, 16, 1).mse),
    ]);
    t.row(vec![
        "L1-Lloyd (AF)".into(),
        format!("{:.5}", reg.get(GridKind::Af, 16, 1).mse),
    ]);
    t.row(vec![
        "CLVQ/Lloyd (HIGGS)".into(),
        format!("{:.5}", reg.get(GridKind::Higgs, 16, 1).mse),
    ]);
    print!("{}", t.render());

    // ---- B + C: rotation vs side-band on heavy-tailed weights ----
    let w = heavy_tail_layer(256, 128, 3);
    let g = 64;
    let mut t = Table::new(
        "Ablation B/C: outlier handling @ ~3.25 bits (heavy-tailed layer)",
        &["method", "bits", "t2"],
    );
    let grid = reg.get(GridKind::Higgs, 8, 1);
    let plain = LutQuantizer::new(grid.clone(), g);
    t.row(vec![
        "grid only (no RHT)".into(),
        format!("{:.2}", plain.bits_per_param(256)),
        format!("{:.5}", plain.quantize("l", &w).rel_sq_err(&w)),
    ]);
    let higgs = HiggsQuantizer::new(grid.clone(), g, 7);
    t.row(vec![
        "RHT + grid (HIGGS)".into(),
        format!("{:.2}", higgs.bits_per_param(256)),
        format!("{:.5}", higgs.quantize("l", &w).rel_sq_err(&w)),
    ]);
    let spqr = OutlierQuantizer::new(RtnQuantizer::new(3, g), 0.01);
    t.row(vec![
        "fp side-band (SpQR-lite)".into(),
        format!("{:.2}", spqr.bits_per_param(256)),
        format!("{:.5}", spqr.quantize("l", &w).rel_sq_err(&w)),
    ]);
    print!("{}", t.render());

    // ---- D: scale group size ----
    let wg = heavy_tail_layer(256, 128, 4);
    let mut t = Table::new(
        "Ablation D: group size (HIGGS n=16 p=1)",
        &["g", "eff_bits", "t2"],
    );
    for g in [16usize, 32, 64, 128, 256] {
        let q = HiggsQuantizer::new(reg.get(GridKind::Higgs, 16, 1), g, 7);
        let ql = q.quantize("l", &wg);
        t.row(vec![
            g.to_string(),
            format!("{:.2}", effective_bits(16, 1, g.min(256))),
            format!("{:.5}", ql.rel_sq_err(&wg)),
        ]);
    }
    print!("{}", t.render());

    // ---- E/F: allocation solvers ----
    let mut rng = Rng::new(9);
    let l_count = 112;
    let db = ErrorDb {
        layers: (0..l_count).map(|i| format!("l{i}")).collect(),
        dims: (0..l_count)
            .map(|i| if i % 3 == 0 { 4_194_304 } else { 11_534_336 })
            .collect(),
        choices: vec![
            GridChoice { id: "b2".into(), bits: 2.25 },
            GridChoice { id: "b3".into(), bits: 3.25 },
            GridChoice { id: "b4".into(), bits: 4.25 },
            GridChoice { id: "b8".into(), bits: 8.25 },
        ],
        t2: (0..l_count)
            .map(|_| {
                let base = 0.05 + rng.uniform() * 0.25;
                vec![base, base * 0.3, base * 0.08, base * 0.001]
            })
            .collect(),
    };
    let alphas = LayerAlphas {
        metric: CalibMetric::Ppl,
        alphas: (0..l_count)
            .map(|i| (format!("l{i}"), 0.2 + rng.uniform() * 8.0))
            .collect(),
        base: 0.0,
        noise_levels: vec![],
    };
    let mut runner = BenchRunner::new();
    let mut t = Table::new(
        "Ablation E: allocation solver quality + runtime (112 layers, b_max=3.25)",
        &["solver", "penalty", "avg_bits", "median_ms"],
    );
    let m_dp = runner.bench("dp", || solve_dp(&db, &alphas, 3.25).unwrap());
    let dp = solve_dp(&db, &alphas, 3.25).unwrap();
    t.row(vec![
        "DP (exact)".into(),
        format!("{:.5}", dp.predicted_penalty),
        format!("{:.3}", dp.avg_bits),
        format!("{:.2}", m_dp.median_ms),
    ]);
    let m_gr = runner.bench("greedy", || solve_greedy(&db, &alphas, 3.25).unwrap());
    let gr = solve_greedy(&db, &alphas, 3.25).unwrap();
    t.row(vec![
        "greedy".into(),
        format!("{:.5}", gr.predicted_penalty),
        format!("{:.3}", gr.avg_bits),
        format!("{:.2}", m_gr.median_ms),
    ]);
    let m_lg = runner.bench("lagrange", || solve_lagrange(&db, &alphas, 3.25).unwrap());
    let lg = solve_lagrange(&db, &alphas, 3.25).unwrap();
    t.row(vec![
        "lagrange".into(),
        format!("{:.5}", lg.predicted_penalty),
        format!("{:.3}", lg.avg_bits),
        format!("{:.2}", m_lg.median_ms),
    ]);
    print!("{}", t.render());
    assert!(dp.predicted_penalty <= gr.predicted_penalty + 1e-9);
    assert!(dp.predicted_penalty <= lg.predicted_penalty + 1e-9);
    eprintln!("ablations done");
}
