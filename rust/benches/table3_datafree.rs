//! Bench: regenerate paper Table 3 (and Tables 7–11 via
//! HIGGS_BENCH_CFG=tiny/small/base) — the data-free method grid:
//! NF / AF / HQQ / HIGGS(p) / dynamic HIGGS × bit tiers, reporting PPL
//! + synthetic task accuracies.

use higgs::experiments::{tables, ExpContext};

fn main() {
    let cfg = std::env::var("HIGGS_BENCH_CFG").unwrap_or_else(|_| "base".into());
    let ctx = match ExpContext::load(&cfg) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("table3: skipping ({e:#})");
            return;
        }
    };
    let t0 = std::time::Instant::now();
    match tables::table3_datafree(&ctx) {
        Ok(table) => {
            print!("{}", table.render());
            eprintln!("table3 completed in {:.1}s", t0.elapsed().as_secs_f64());
        }
        Err(e) => eprintln!("table3 failed: {e:#}"),
    }
}
