//! Bench: regenerate paper Table 6 (Appendix G) — FLUTE qmm kernel
//! throughput with vs without the online activation Hadamard transform,
//! across batch {1,4,16} × wbits {2,3,4}.

use higgs::experiments::{tables, ExpContext};

fn main() {
    let cfg = std::env::var("HIGGS_BENCH_CFG").unwrap_or_else(|_| "base".into());
    let ctx = match ExpContext::load(&cfg) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("table6: skipping ({e:#})");
            return;
        }
    };
    let t0 = std::time::Instant::now();
    match tables::table6_hadamard_overhead(&ctx) {
        Ok(table) => {
            print!("{}", table.render());
            eprintln!("table6 completed in {:.1}s", t0.elapsed().as_secs_f64());
        }
        Err(e) => eprintln!("table6 failed: {e:#}"),
    }
}
