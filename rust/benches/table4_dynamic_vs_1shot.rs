//! Bench: regenerate paper Table 4 — dynamic HIGGS (data-free KL and
//! PPL-calibrated) vs GPTQ at matched budgets.

use higgs::experiments::{tables, ExpContext};

fn main() {
    let cfg = std::env::var("HIGGS_BENCH_CFG").unwrap_or_else(|_| "base".into());
    let ctx = match ExpContext::load(&cfg) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("table4: skipping ({e:#})");
            return;
        }
    };
    let t0 = std::time::Instant::now();
    match tables::table4_dynamic_vs_1shot(&ctx) {
        Ok(table) => {
            print!("{}", table.render());
            eprintln!("table4 completed in {:.1}s", t0.elapsed().as_secs_f64());
        }
        Err(e) => eprintln!("table4 failed: {e:#}"),
    }
}
