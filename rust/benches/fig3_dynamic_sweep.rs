//! Bench: regenerate paper Figure 3 — PPL vs bitwidth budget for
//! dynamic (non-uniform) HIGGS, with the linear-model prediction.

use higgs::experiments::{figures, ExpContext};
use higgs::linearity::calibrate::CalibMetric;

fn main() {
    let cfg = std::env::var("HIGGS_BENCH_CFG").unwrap_or_else(|_| "base".into());
    let ctx = match ExpContext::load(&cfg) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("fig3: skipping ({e:#})");
            return;
        }
    };
    let t0 = std::time::Instant::now();
    match figures::fig3_dynamic_sweep(&ctx, CalibMetric::Kl) {
        Ok((series, table)) => {
            print!("{}", series.render());
            print!("{}", table.render());
            eprintln!("fig3 completed in {:.1}s", t0.elapsed().as_secs_f64());
        }
        Err(e) => eprintln!("fig3 failed: {e:#}"),
    }
}
