//! Bench: regenerate paper Figure 1 — measured vs predicted PPL for
//! uniform HIGGS quantization across the bit range.
//!
//! Run: `cargo bench --bench fig1_error_model` (HIGGS_BENCH_QUICK=1 for
//! a fast pass). Requires `make artifacts` and a trained checkpoint
//! (`higgs train --config base`).

use higgs::experiments::{figures, ExpContext};

fn main() {
    let cfg = std::env::var("HIGGS_BENCH_CFG").unwrap_or_else(|_| "base".into());
    let ctx = match ExpContext::load(&cfg) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("fig1: skipping ({e:#}); run `make artifacts` first");
            return;
        }
    };
    let t0 = std::time::Instant::now();
    match figures::fig1_error_model(&ctx) {
        Ok((series, table)) => {
            print!("{}", series.render());
            print!("{}", table.render());
            eprintln!("fig1 completed in {:.1}s", t0.elapsed().as_secs_f64());
        }
        Err(e) => eprintln!("fig1 failed: {e:#}"),
    }
}
