//! Bench: regenerate paper Figure 4/5 (Appendix E) — diagonal dominance
//! of the scaled Hessian D*∇²φD* (Assumption 3 validation). Uses the
//! `tiny` model by default (finite differences over grad executions).

use higgs::experiments::{figures, ExpContext};

fn main() {
    let cfg = std::env::var("HIGGS_BENCH_CFG").unwrap_or_else(|_| "tiny".into());
    let per_layer = if std::env::var("HIGGS_BENCH_QUICK").is_ok() { 4 } else { 12 };
    let ctx = match ExpContext::load(&cfg) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("fig4: skipping ({e:#})");
            return;
        }
    };
    let t0 = std::time::Instant::now();
    match figures::fig4_hessian(&ctx, per_layer) {
        Ok(table) => {
            print!("{}", table.render());
            eprintln!("fig4 completed in {:.1}s", t0.elapsed().as_secs_f64());
        }
        Err(e) => eprintln!("fig4 failed: {e:#}"),
    }
}
