//! Bench: regenerate paper Table 2 — 1-shot (GPTQ-family) PPL at
//! wbits ≈ {2,3,4}: GPTQ vs GPTQ+HIGGS(p=2) vs data-free HIGGS.

use higgs::experiments::{tables, ExpContext};

fn main() {
    let cfg = std::env::var("HIGGS_BENCH_CFG").unwrap_or_else(|_| "base".into());
    let ctx = match ExpContext::load(&cfg) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("table2: skipping ({e:#})");
            return;
        }
    };
    let t0 = std::time::Instant::now();
    match tables::table2_gptq(&ctx) {
        Ok(table) => {
            print!("{}", table.render());
            eprintln!("table2 completed in {:.1}s", t0.elapsed().as_secs_f64());
        }
        Err(e) => eprintln!("table2 failed: {e:#}"),
    }
}
