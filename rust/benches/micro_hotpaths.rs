//! Micro-benchmarks of the framework hot paths (the §Perf inputs):
//! FWHT, grid nearest-neighbour (brute-force scan vs projection index),
//! HIGGS layer quantization throughput (serial reference vs blocked
//! multithreaded encode), fused decode (blocked parallel dequantize vs
//! serial reference, decode-from-packed, streaming error measurement),
//! bit-packing, DP allocation, qmm kernel executions at serving shapes,
//! the tiled block gather, pipeline-parallel serving throughput at
//! 1/2/4 shards plus per-frame transport overhead, and the network
//! daemon (request wire codec roundtrip + loopback TCP tokens/s).
//!
//! Emits `BENCH_hotpaths.json` (override with `HIGGS_BENCH_JSON`) with
//! (op, ns/iter, throughput) rows so the perf trajectory is tracked
//! across PRs — see `PERF.md` for how to read it. The indexed/blocked
//! fast paths are asserted equal to their reference oracles before
//! timing, so a broken optimization can't report a good number.

use higgs::grids::registry::GridRegistry;
use higgs::grids::GridKind;
use higgs::hadamard::{fwht, rht_forward, signs_for};
use higgs::quant::higgs::HiggsQuantizer;
use higgs::quant::lut::LutQuantizer;
use higgs::quant::packing::{pack, unpack};
use higgs::quant::{QuantData, Quantizer};
use higgs::tensor::Tensor;
use higgs::util::bench::BenchRunner;
use higgs::util::prng::Rng;

/// Raw f32 bits — the decode correctness gates compare bit patterns,
/// not `==` (which would let a 0.0 → -0.0 regression slip through).
fn bits_of(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn main() {
    let mut r = BenchRunner::new();
    let mut rng = Rng::new(1);

    // FWHT over serving-typical group sizes
    for g in [64usize, 256, 1024] {
        let mut v = rng.normal_vec(g);
        r.bench_items(&format!("fwht_g{g}_x1000"), 1000.0, || {
            for _ in 0..1000 {
                fwht(&mut v);
            }
            v[0]
        });
    }
    // grouped RHT over a full layer column set
    {
        let n = 64 * 512;
        let mut x = rng.normal_vec(n);
        let signs = signs_for(0, "bench", n);
        r.bench_items("rht_forward_32k", n as f64, || {
            rht_forward(&mut x, &signs, 64);
            x[0]
        });
    }

    // grid nearest-neighbour: indexed Grid::nearest vs the brute-force
    // reference scan on identical probes
    let reg = GridRegistry::new();
    for (n, p) in [(16usize, 1usize), (256, 2), (4096, 2)] {
        let grid = reg.get(GridKind::Higgs, n, p);
        let probes: Vec<f32> = rng.normal_vec(1024 * p);
        // correctness gate: the indexed path must match the scan exactly
        for c in probes.chunks(p) {
            assert_eq!(
                grid.nearest(c),
                grid.nearest_bruteforce(c),
                "indexed nearest diverged from scan at n={n} p={p}"
            );
        }
        r.bench_items(&format!("nearest_n{n}_p{p}_x1024"), 1024.0, || {
            let mut acc = 0usize;
            for c in probes.chunks(p) {
                acc += grid.nearest(c);
            }
            acc
        });
        r.bench_items(&format!("nearest_bruteforce_n{n}_p{p}_x1024"), 1024.0, || {
            let mut acc = 0usize;
            for c in probes.chunks(p) {
                acc += grid.nearest_bruteforce(c);
            }
            acc
        });
    }

    // HIGGS quantization throughput on a base-sized layer (512x192):
    // blocked multithreaded encode vs the serial reference
    {
        let w = Tensor::from_vec(&[512, 192], rng.normal_vec(512 * 192));
        let grid = reg.get(GridKind::Higgs, 256, 2);
        let q = HiggsQuantizer::new(grid, 64, 7);
        let fast = q.quantize("l", &w);
        let slow = q.quantize_reference("l", &w);
        match (&fast.data, &slow.data) {
            (
                QuantData::Lut { codes: ca, scales: sa, .. },
                QuantData::Lut { codes: cb, scales: sb, .. },
            ) => {
                assert_eq!(ca, cb, "blocked encode codes diverged from reference");
                assert_eq!(sa, sb, "blocked encode scales diverged from reference");
            }
            _ => unreachable!(),
        }
        let params = 512.0 * 192.0;
        let m = r.bench_items("higgs_quantize_512x192", params, || q.quantize("l", &w));
        eprintln!("  -> {:.2} Mparam/s (blocked parallel)", m.throughput(params) / 1e6);
        let m = r.bench_items("higgs_quantize_serial_512x192", params, || {
            q.quantize_reference("l", &w)
        });
        eprintln!("  -> {:.2} Mparam/s (serial reference)", m.throughput(params) / 1e6);
    }

    // fused decode: blocked parallel dequantize vs the serial
    // reference on a 1024x1024 LUT layer (the PR acceptance target),
    // decode-from-packed, the batched-inverse-RHT HIGGS decode, and
    // the streaming error measurement vs the materializing one
    {
        let w = Tensor::from_vec(&[1024, 1024], rng.normal_vec(1024 * 1024));
        let params = 1024.0 * 1024.0;
        let ql = LutQuantizer::new(reg.get(GridKind::Nf, 16, 1), 64).quantize("l", &w);
        // correctness gates: fast paths must match the reference
        // bit-for-bit before any timing happens
        let reference = ql.dequantize_reference();
        assert_eq!(
            bits_of(&ql.dequantize().data),
            bits_of(&reference.data),
            "blocked dequantize diverged"
        );
        let pc = ql.packed_codes();
        assert_eq!(
            bits_of(&ql.dequantize_from_packed(&pc).data),
            bits_of(&reference.data),
            "packed dequantize diverged"
        );
        let m = r.bench_items("dequant_dense_1024x1024", params, || ql.dequantize());
        eprintln!("  -> {:.2} Mparam/s (blocked parallel)", m.throughput(params) / 1e6);
        let m = r.bench_items("dequant_dense_serial_1024x1024", params, || {
            ql.dequantize_reference()
        });
        eprintln!("  -> {:.2} Mparam/s (serial reference)", m.throughput(params) / 1e6);
        r.bench_items("dequant_from_packed_1024x1024", params, || {
            ql.dequantize_from_packed(&pc)
        });

        // rotated HIGGS layer: decode includes the inverse RHT, batched
        // per block on the fast path, per-column scalar on the serial one
        let qh = HiggsQuantizer::new(reg.get(GridKind::Higgs, 256, 2), 64, 7);
        let qlh = qh.quantize("h", &w);
        assert_eq!(
            bits_of(&qlh.dequantize().data),
            bits_of(&qlh.dequantize_reference().data),
            "blocked rotated dequantize diverged"
        );
        r.bench_items("dequant_rht_1024x1024", params, || qlh.dequantize());
        r.bench_items("dequant_rht_serial_1024x1024", params, || qlh.dequantize_reference());

        // streaming rel_sq_err (no dense materialization) vs the
        // materializing reference — the per-cell cost of an ErrorDb
        // build for quantizers without an encode-time t² fast path
        let fast = ql.rel_sq_err(&w);
        let slow = ql.rel_sq_err_reference(&w);
        assert!(
            (fast - slow).abs() <= 1e-12 + 1e-9 * slow.abs(),
            "streaming rel_sq_err diverged: {fast} vs {slow}"
        );
        let m = r.bench_items("errordb_streaming_relerr_1024x1024", params, || {
            ql.rel_sq_err(&w)
        });
        eprintln!("  -> {:.2} Mparam/s (streaming)", m.throughput(params) / 1e6);
        r.bench_items("errordb_materialized_relerr_1024x1024", params, || {
            ql.rel_sq_err_reference(&w)
        });
    }

    // bit packing
    {
        let codes: Vec<u32> = (0..98304).map(|_| rng.below(16) as u32).collect();
        r.bench_items("pack_98k_4bit", 98304.0, || pack(&codes, 4));
        let packed = pack(&codes, 4);
        r.bench_items("unpack_98k_4bit", 98304.0, || unpack(&packed, codes.len(), 4));
    }

    // QuantArtifact persistence: save the packed planes, then the
    // serving cold start (load + decode-from-packed) vs re-quantizing
    // from scratch — the "quantize once, serve many times" ratio
    {
        use higgs::quant::artifact::QuantArtifact;
        use higgs::quant::QuantizedModel;
        let w = Tensor::from_vec(&[1024, 1024], rng.normal_vec(1024 * 1024));
        let params = 1024.0 * 1024.0;
        let q = HiggsQuantizer::new(reg.get(GridKind::Higgs, 256, 2), 64, 7);
        let qm = QuantizedModel::from_layers(vec![q.quantize("l", &w)]);
        let art = QuantArtifact::from_model("bench", &qm);
        let path = std::env::temp_dir()
            .join(format!("higgs_bench_artifact_{}.qa", std::process::id()));
        art.save(&path).unwrap();
        // correctness gate: the loaded artifact must reproduce the
        // in-memory model bit-for-bit before any timing happens
        let loaded = QuantArtifact::load(&path).unwrap();
        assert_eq!(
            bits_of(&loaded.layers[0].dequantize().data),
            bits_of(&qm.layers[0].dequantize().data),
            "artifact roundtrip diverged"
        );
        assert_eq!(
            loaded.packed_avg_bits().to_bits(),
            qm.packed_avg_bits().to_bits(),
            "packed bits accounting diverged"
        );
        r.bench_items("artifact_save_1024x1024", params, || art.save(&path).unwrap());
        let m = r.bench_items("artifact_load_cold_start", params, || {
            let a = QuantArtifact::load(&path).unwrap();
            a.layers[0].dequantize()
        });
        eprintln!(
            "  -> artifact cold start: {:.2} Mparam/s (load + decode-from-packed)",
            m.throughput(params) / 1e6
        );
        let m = r.bench_items("artifact_requantize_1024x1024", params, || {
            q.quantize("l", &w)
        });
        eprintln!("  -> re-quantize: {:.2} Mparam/s", m.throughput(params) / 1e6);
        let _ = std::fs::remove_file(&path);
    }

    // ArtifactReader: single-layer lazy load (ranged read + per-plane
    // checksum + decode) vs paying the full-file load for one layer —
    // the sharded cold-start unit of work on an 8-layer artifact
    {
        use higgs::quant::artifact::QuantArtifact;
        use higgs::quant::reader::ArtifactReader;
        use higgs::quant::QuantizedModel;
        let q2 = HiggsQuantizer::new(reg.get(GridKind::Higgs, 16, 2), 64, 7);
        let q4 = HiggsQuantizer::new(reg.get(GridKind::Higgs, 256, 2), 64, 7);
        let layers: Vec<_> = (0..8)
            .map(|i| {
                let w = Tensor::from_vec(&[256, 256], rng.normal_vec(256 * 256));
                let q: &HiggsQuantizer = if i % 2 == 0 { &q2 } else { &q4 };
                q.quantize(&format!("l{i}"), &w)
            })
            .collect();
        let qm = QuantizedModel::from_layers(layers);
        let art = QuantArtifact::from_model("bench8", &qm);
        let path = std::env::temp_dir()
            .join(format!("higgs_bench_reader_{}.qa", std::process::id()));
        art.save(&path).unwrap();
        let reader = ArtifactReader::open(&path).unwrap();
        // correctness gate: the lazy single-layer load is bit-identical
        // to the same layer out of the full load
        let full = QuantArtifact::load(&path).unwrap();
        assert_eq!(
            bits_of(&reader.load_layer("l3").unwrap().dequantize().data),
            bits_of(&full.get("l3").unwrap().dequantize().data),
            "lazy layer load diverged from full load"
        );
        let layer_params = 256.0 * 256.0;
        let m = r.bench_items("reader_single_layer_load", layer_params, || {
            reader.load_layer("l3").unwrap().dequantize()
        });
        eprintln!(
            "  -> reader single-layer load: {:.2} Mparam/s (1/8 of the planes read)",
            m.throughput(layer_params) / 1e6
        );
        let m = r.bench_items("artifact_full_load_one_layer", layer_params, || {
            QuantArtifact::load(&path).unwrap().get("l3").unwrap().dequantize()
        });
        eprintln!(
            "  -> full-load baseline for one layer: {:.2} Mparam/s",
            m.throughput(layer_params) / 1e6
        );
        let _ = std::fs::remove_file(&path);
    }

    // DP allocation at paper scale: 224 layers × 8 grid choices
    {
        use higgs::alloc::{solve_dp, ErrorDb, GridChoice};
        use higgs::linearity::calibrate::{CalibMetric, LayerAlphas};
        let l_count = 224;
        let db = ErrorDb {
            layers: (0..l_count).map(|i| format!("l{i}")).collect(),
            dims: (0..l_count)
                .map(|i| if i % 3 == 0 { 16_777_216 } else { 58_720_256 })
                .collect(),
            choices: (0..8)
                .map(|j| GridChoice { id: format!("g{j}"), bits: 2.0 + 0.25 * j as f64 + 0.25 })
                .collect(),
            t2: (0..l_count)
                .map(|i| (0..8).map(|j| 0.2 / (1.5f64.powi(j)) * (1.0 + (i % 7) as f64 * 0.1)).collect())
                .collect(),
        };
        let alphas = LayerAlphas {
            metric: CalibMetric::Ppl,
            alphas: (0..l_count).map(|i| (format!("l{i}"), 1.0 + (i % 5) as f64)).collect(),
            base: 0.0,
            noise_levels: vec![],
        };
        let m = r.bench("dp_alloc_224layers_8choices", || {
            solve_dp(&db, &alphas, 3.25).unwrap()
        });
        eprintln!("  -> LLM-scale DP solve: {:.1} ms", m.median_ms);
    }

    // ErrorDb build (every layer × every grid choice, pool-parallel)
    // + mixed-precision encode of the resulting DP allocation
    {
        use higgs::alloc::errordb::{build_error_db, higgs_test_choices, quantize_allocation};
        use higgs::alloc::solve_dp;
        use higgs::linearity::calibrate::{CalibMetric, LayerAlphas};
        use higgs::model::fixture;

        let cfg = fixture::tiny_config();
        let w = fixture::tiny_weights(3);
        let choices = higgs_test_choices(cfg.group, 7);
        let cells = (cfg.linear_params() * choices.len()) as f64;
        let build = build_error_db(&w, &choices).unwrap();
        let m = r.bench_items("errordb_build_tiny_3choices", cells, || {
            build_error_db(&w, &choices).unwrap()
        });
        eprintln!("  -> ErrorDb build: {:.2} Mparam-cells/s", m.throughput(cells) / 1e6);

        let alphas = LayerAlphas {
            metric: CalibMetric::Ppl,
            alphas: build
                .db
                .layers
                .iter()
                .enumerate()
                .map(|(i, n)| (n.clone(), 1.0 + (i % 5) as f64))
                .collect(),
            base: 0.0,
            noise_levels: vec![],
        };
        let sol = solve_dp(&build.db, &alphas, 4.0).unwrap();
        let params = cfg.linear_params() as f64;
        let m = r.bench_items("mixed_encode_tiny", params, || {
            quantize_allocation(&w, &choices, &sol).unwrap()
        });
        eprintln!("  -> mixed encode: {:.2} Mparam/s", m.throughput(params) / 1e6);

        // Mixed-backend param assembly (serve-bench engine-construction
        // cold start): per-layer dense params from the pool-parallel
        // decode fan-out
        {
            use higgs::model::Manifest;
            use higgs::quant::artifact::QuantArtifact;
            use higgs::runtime::HostArg;
            use higgs::serve::{Backend, PlaneStore, QuantSource};
            let man = Manifest::parse(&fixture::dense_manifest_text(&cfg)).unwrap();
            let qm = quantize_allocation(&w, &choices, &sol).unwrap();
            let m = r.bench_items("mixed_build_params_tiny", params, || {
                Backend::Mixed.build_params(&man, &w, Some(&qm)).unwrap()
            });
            eprintln!("  -> mixed build_params: {:.2} Mparam/s", m.throughput(params) / 1e6);

            // Engine-construction param provisioning from an artifact:
            // the PR 4 baseline decoded every layer once PER manifest
            // (decode + prefill = 2× decodes); the shared PlaneStore
            // decodes once and clones. Both benched on the same two
            // dense manifests the Mixed engine uses.
            let art = QuantArtifact::from_model(&cfg.name, &qm);
            let src = QuantSource::Artifact(&art);
            let shared = || {
                let store = PlaneStore::build_for(src, &[&man, &man]).unwrap();
                let d = Backend::Mixed.build_params_with(&man, &w, Some(src), &store).unwrap();
                let p = Backend::Dense.build_params_with(&man, &w, Some(src), &store).unwrap();
                (d, p)
            };
            let double = || {
                let d = Backend::Mixed.build_params_from(&man, &w, Some(src)).unwrap();
                let p = Backend::Dense.build_params_from(&man, &w, Some(src)).unwrap();
                (d, p)
            };
            // correctness + decode-count gates before timing: shared
            // decodes each layer once, the baseline twice, params
            // bit-identical
            let nlayers = qm.layers.len() as u64;
            let c0 = higgs::quant::decode::dense_decode_count();
            let (sd, sp) = shared();
            let c1 = higgs::quant::decode::dense_decode_count();
            let (dd, dp) = double();
            let c2 = higgs::quant::decode::dense_decode_count();
            assert_eq!(c1 - c0, nlayers, "shared planes must decode each layer once");
            assert_eq!(c2 - c1, 2 * nlayers, "baseline decodes per manifest");
            for (a, b) in sd.iter().zip(&dd).chain(sp.iter().zip(&dp)) {
                match (a, b) {
                    (HostArg::F32(x, _), HostArg::F32(y, _)) => {
                        assert_eq!(bits_of(x), bits_of(y), "shared-planes params diverged")
                    }
                    (HostArg::I32(x, _), HostArg::I32(y, _)) => assert_eq!(x, y),
                    _ => panic!("param kind diverged"),
                }
            }
            let m = r.bench_items("engine_cold_start_shared_planes", 2.0 * params, &shared);
            eprintln!(
                "  -> shared-planes provisioning (2 manifests): {:.2} Mparam/s",
                m.throughput(2.0 * params) / 1e6
            );
            let m = r.bench_items("engine_cold_start_double_decode", 2.0 * params, &double);
            eprintln!(
                "  -> double-decode baseline (2 manifests): {:.2} Mparam/s",
                m.throughput(2.0 * params) / 1e6
            );
        }

        // ErrorDb build through the STREAMING decode measurement:
        // non-HIGGS choices have no encode-time t² fast path, so every
        // (layer, choice) cell pays a decode — now fused + blocked
        // instead of a dense materialize-and-compare
        use higgs::alloc::errordb::lut_test_choices;
        let lut_choices = lut_test_choices(cfg.group);
        let lut_cells = (cfg.linear_params() * lut_choices.len()) as f64;
        let m = r.bench_items("errordb_streaming_build_tiny_lut3", lut_cells, || {
            build_error_db(&w, &lut_choices).unwrap()
        });
        eprintln!(
            "  -> ErrorDb build (streaming, LUT choices): {:.2} Mparam-cells/s",
            m.throughput(lut_cells) / 1e6
        );
    }

    // qmm kernel executions (if artifacts exist)
    if higgs::artifacts_dir().join("qmm_dense_m1.hlo.txt").exists() {
        let engine = higgs::runtime::Engine::new().unwrap();
        let (k, n_cols, g) = (512usize, 512usize, 64usize);
        for m in [1usize, 16] {
            let x = higgs::runtime::HostArg::F32(rng.normal_vec(m * k), vec![m, k]);
            let dense = engine.load(&format!("qmm_dense_m{m}")).unwrap();
            let w = higgs::runtime::HostArg::F32(rng.normal_vec(k * n_cols), vec![k, n_cols]);
            r.bench(&format!("qmm_dense_m{m}"), || {
                engine.run(&dense, &[x.clone(), w.clone()]).unwrap()
            });
            let flute = engine.load(&format!("qmm_flute_p2_b4_m{m}")).unwrap();
            let codes = higgs::runtime::HostArg::I32(
                (0..(k / 2) * n_cols).map(|_| rng.below(256) as i32).collect(),
                vec![k / 2, n_cols],
            );
            let scales =
                higgs::runtime::HostArg::F32(rng.normal_vec((k / g) * n_cols), vec![k / g, n_cols]);
            let lut = higgs::runtime::HostArg::F32(rng.normal_vec(512), vec![256, 2]);
            r.bench(&format!("qmm_flute_p2_b4_m{m}"), || {
                engine
                    .run(&flute, &[x.clone(), codes.clone(), scales.clone(), lut.clone()])
                    .unwrap()
            });
        }
    }

    // KV admission: slot-strided vs the full-splice reference, across
    // live batch sizes. The acceptance claim is in the BYTE accounting
    // (asserted before timing): strided admission moves the same bytes
    // per admit at batch 4 and batch 16, the full splice scales with
    // the whole cache.
    {
        use higgs::serve::{FullKv, KvLayout, SlotKv};
        let layout = KvLayout { layers: 4, heads: 4, seq: 64, d_head: 16 };
        let mut strided_bytes_per_admit = Vec::new();
        for batch in [4usize, 16] {
            let kc = rng.normal_vec(layout.full_elems(batch));
            let vc = rng.normal_vec(layout.full_elems(batch));
            let mut s = SlotKv::new(layout, batch).unwrap();
            let mut f = FullKv::new(layout, batch).unwrap();
            s.admit_from_full(&[0], &kc, &vc).unwrap();
            f.admit_reference(&[0], &kc, &vc).unwrap();
            strided_bytes_per_admit.push(s.admit_bytes);
            assert_eq!(
                f.admit_bytes,
                4 * layout.full_elems(batch) as u64 * 4,
                "full splice must move the whole cache"
            );
            // one-slot admission, timed (bytes per iteration = what one
            // admit moves — flat for strided, growing for full-splice)
            r.bench_items(&format!("kv_admit_strided_b{batch}"), 1.0, || {
                s.admit_from_full(&[0], &kc, &vc).unwrap()
            });
            r.bench_items(&format!("kv_admit_fullsplice_b{batch}"), 1.0, || {
                f.admit_reference(&[0], &kc, &vc).unwrap()
            });
        }
        assert_eq!(
            strided_bytes_per_admit[0], strided_bytes_per_admit[1],
            "strided admission bytes must be independent of the live batch size"
        );
        eprintln!(
            "  -> strided admit moves {} bytes at batch 4 AND 16; full splice {} vs {}",
            strided_bytes_per_admit[0],
            4 * layout.full_elems(4) * 4,
            4 * layout.full_elems(16) * 4,
        );
    }

    // churn throughput: continuous batching on the strided path vs the
    // drain-between-batches baseline on the full-splice path, same
    // Poisson-ish workload with mixed prompt lengths. Gates before
    // timing: everything completes, continuous actually admits
    // mid-batch, strided moves fewer admission bytes.
    {
        use higgs::serve::{run_churn, ChurnConfig, KvMode};
        let base = ChurnConfig {
            long_frac: 0.25,
            mean_gap_steps: 1.5,
            ..Default::default()
        };
        let cont = ChurnConfig { mode: KvMode::Strided, ..base.clone() };
        let drain = ChurnConfig { drain: true, mode: KvMode::FullSplice, ..base.clone() };
        let both = run_churn(&ChurnConfig { mode: KvMode::Both, ..base.clone() }).unwrap();
        let rc = run_churn(&cont).unwrap();
        let rd = run_churn(&drain).unwrap();
        assert_eq!(rc.completions, base.n_requests as u64);
        assert_eq!(rd.completions, base.n_requests as u64);
        assert!(rc.mid_batch_admissions > 0, "continuous run never admitted mid-batch");
        assert_eq!(rd.mid_batch_admissions, 0);
        assert!(rc.steps < rd.steps, "continuous must finish in fewer decode steps");
        assert!(
            both.admit_bytes_strided < both.admit_bytes_fullsplice,
            "strided admission must move fewer bytes"
        );
        let toks = rc.total_generated as f64;
        let m = r.bench_items("churn_continuous_strided", toks, || run_churn(&cont).unwrap());
        eprintln!("  -> continuous+strided churn: {:.1} tok/s", m.throughput(toks));
        let toks_d = rd.total_generated as f64;
        let m = r.bench_items("churn_drain_fullsplice", toks_d, || run_churn(&drain).unwrap());
        eprintln!("  -> drain+fullsplice baseline: {:.1} tok/s", m.throughput(toks_d));
    }

    // SIMD-friendly block gather: the tiled micro-transpose feeding the
    // blocked HIGGS encode vs the naive per-element scatter it replaced
    // — a pure copy permutation, equality-gated bit-for-bit first
    {
        use higgs::quant::higgs::gather_block_colmajor;
        let (k, n) = (1024usize, 1024usize);
        let src = rng.normal_vec(k * n);
        let (j0, bcols) = (512usize, 32usize);
        let mut tiled = vec![0.0f32; bcols * k];
        let mut naive = vec![0.0f32; bcols * k];
        gather_block_colmajor(&src, k, n, j0, bcols, &mut tiled);
        for kk in 0..k {
            let row = &src[kk * n + j0..kk * n + j0 + bcols];
            for (b, &val) in row.iter().enumerate() {
                naive[b * k + kk] = val;
            }
        }
        assert_eq!(bits_of(&tiled), bits_of(&naive), "tiled gather diverged from naive");
        let elems = (bcols * k) as f64;
        let m = r.bench_items("gather_block_1024", elems, || {
            gather_block_colmajor(&src, k, n, j0, bcols, &mut tiled);
            tiled[0]
        });
        eprintln!("  -> tiled block gather: {:.1} Melem/s", m.throughput(elems) / 1e6);
        r.bench_items("gather_block_naive_1024", elems, || {
            for kk in 0..k {
                let row = &src[kk * n + j0..kk * n + j0 + bcols];
                for (b, &val) in row.iter().enumerate() {
                    naive[b * k + kk] = val;
                }
            }
            naive[0]
        });
    }

    // pipeline-parallel serving: tokens/s at 1/2/4 shards on one churn
    // workload (tokens asserted identical across shard counts before
    // timing — sharding is an execution strategy, not a different
    // model), per-ring cold-start bytes, and the frame encode/parse
    // cost paid on every shard hop
    {
        use higgs::serve::churn::churn_arrivals;
        use higgs::serve::transport::{FRAME_DECODE, WIRE_OVERHEAD};
        use higgs::serve::{
            run_pipeline, ActivationFrame, ChurnConfig, PipelineConfig, PipelineSource,
        };
        let mk = |shards: usize| PipelineConfig {
            shards,
            micro_batches: 2,
            layers: 8,
            ..Default::default()
        };
        let workload = ChurnConfig { n_requests: 16, ..Default::default() };
        let oracle =
            run_pipeline(&mk(1), &PipelineSource::Synthetic, churn_arrivals(&workload)).unwrap();
        let toks: f64 = oracle.completions.iter().map(|c| c.tokens.len() as f64).sum();
        assert!(toks > 0.0, "pipeline workload generated no tokens");
        for shards in [2usize, 4] {
            let rep =
                run_pipeline(&mk(shards), &PipelineSource::Synthetic, churn_arrivals(&workload))
                    .unwrap();
            assert_eq!(rep.completions.len(), oracle.completions.len());
            for (a, b) in oracle.completions.iter().zip(&rep.completions) {
                assert_eq!(
                    (a.id, &a.tokens),
                    (b.id, &b.tokens),
                    "pipeline tokens diverged at {shards} shards"
                );
            }
            eprintln!(
                "  -> {shards}-shard ring: cold start {} bytes, {} frames / {} wire bytes, bubble {:.1} ms",
                rep.cold_start_bytes(),
                rep.total_frames(),
                rep.total_wire_bytes(),
                rep.metrics.pipeline_bubble_ms,
            );
        }
        for shards in [1usize, 2, 4] {
            let cfg = mk(shards);
            let m = r.bench_items(&format!("pipeline_tokens_s{shards}"), toks, || {
                run_pipeline(&cfg, &PipelineSource::Synthetic, churn_arrivals(&workload)).unwrap()
            });
            eprintln!("  -> pipeline {shards} shard(s): {:.1} tok/s", m.throughput(toks));
        }
        // per-frame transport overhead: full wire roundtrip (serialize,
        // length/checksum framing, parse + verify) of a decode frame
        let frame = ActivationFrame {
            kind: FRAME_DECODE,
            mb: 0,
            step: 1,
            rows: 4,
            cols: 8,
            active: 0xF,
            pos: vec![3, 4, 5, 6],
            data: rng.normal_vec(32),
        };
        let rt = ActivationFrame::from_bytes(&frame.to_bytes()).unwrap();
        assert_eq!(rt, frame, "frame wire roundtrip diverged");
        eprintln!(
            "  -> frame wire size: {} bytes ({} of them length/checksum framing)",
            frame.wire_len(),
            WIRE_OVERHEAD
        );
        r.bench_items("pipeline_frame_roundtrip", 1.0, || {
            ActivationFrame::from_bytes(&frame.to_bytes()).unwrap()
        });
    }

    // daemon wire protocol: per-request frame encode/parse cost (the
    // Submit message a TCP client pays on every request), then
    // end-to-end loopback serving throughput — N requests over one
    // connection through accept loop, core, coordinator, and back
    {
        use higgs::serve::{
            request_many, ClientOutcome, ClientRequest, Daemon, DaemonConfig, PipelineConfig,
            PipelineSource, WireMsg,
        };
        let submit = WireMsg::Submit {
            id: 7,
            prompt: (0..16).map(|i| i as i32).collect(),
            max_new: 8,
            deadline_ms: 250,
        };
        let rt = WireMsg::from_bytes(&submit.to_bytes()).unwrap();
        assert_eq!(rt, submit, "wire roundtrip diverged");
        r.bench_items("wire_frame_roundtrip", 1.0, || {
            WireMsg::from_bytes(&submit.to_bytes()).unwrap()
        });

        let cfg = DaemonConfig {
            pipeline: PipelineConfig { shards: 2, batch: 4, layers: 6, ..Default::default() },
            ..Default::default()
        };
        let reqs: Vec<ClientRequest> = (1..=8u64)
            .map(|id| ClientRequest {
                id,
                prompt: vec![id as i32, 3, 5],
                max_new: 4,
                deadline_ms: 0,
            })
            .collect();
        let daemon = Daemon::start(cfg, PipelineSource::Synthetic).unwrap();
        let warm = request_many(daemon.addr(), &reqs).unwrap();
        let toks: f64 = warm
            .iter()
            .map(|(_, o)| match o {
                ClientOutcome::Done { tokens, .. } => tokens.len() as f64,
                other => panic!("bench warmup request failed: {other:?}"),
            })
            .sum();
        assert!(toks > 0.0, "daemon warmup generated no tokens");
        let addr = daemon.addr().to_string();
        let m = r.bench_items("daemon_loopback_tokens_s", toks, || {
            request_many(&addr, &reqs).unwrap()
        });
        eprintln!("  -> daemon loopback: {:.1} tok/s over TCP", m.throughput(toks));
        let rep = daemon.finish().unwrap();
        assert_eq!(rep.wire_errors, 0, "bench run must be wire-clean");
    }

    // machine-readable perf record (tracked across PRs)
    let json_path = std::env::var("HIGGS_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_hotpaths.json".to_string());
    match r.write_json(std::path::Path::new(&json_path)) {
        Ok(()) => eprintln!("wrote {json_path} ({} measurements)", r.results.len()),
        Err(e) => eprintln!("WARNING: could not write {json_path}: {e}"),
    }
    eprintln!("micro_hotpaths done ({} measurements)", r.results.len());
}
