//! Bench: regenerate paper Table 1 — end-to-end decode throughput
//! (tok/s) by backend (fp16 / uniform-MARLIN / NF-LUT / FLUTE-HIGGS)
//! × batch size {1,4,16} × wbits {2,3,4} through the serving engine.

use higgs::experiments::{tables, ExpContext};

fn main() {
    let cfg = std::env::var("HIGGS_BENCH_CFG").unwrap_or_else(|_| "base".into());
    let ctx = match ExpContext::load(&cfg) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("table1: skipping ({e:#})");
            return;
        }
    };
    let t0 = std::time::Instant::now();
    match tables::table1_throughput(&ctx) {
        Ok(table) => {
            print!("{}", table.render());
            eprintln!("table1 completed in {:.1}s", t0.elapsed().as_secs_f64());
        }
        Err(e) => eprintln!("table1 failed: {e:#}"),
    }
}
