//! Serving example: run a request trace through the router with a
//! FLUTE-HIGGS quantized model and report latency/throughput — the
//! Table-1 measurement path as a library consumer would use it.
//!
//! ```bash
//! ./target/release/higgs train --config base   # once
//! cargo run --release --example serve_trace -- base flute4 4
//! ```

use higgs::config::ModelConfig;
use higgs::grids::GridKind;
use higgs::model::Weights;
use higgs::quant::higgs::HiggsQuantizer;
use higgs::quant::QuantizedModel;
use higgs::runtime::Engine;
use higgs::serve::trace::{generate_trace, TraceConfig};
use higgs::serve::{Backend, Router, RouterConfig};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cfg_name = args.first().cloned().unwrap_or_else(|| "tiny".into());
    let backend = match args.get(1).map(|s| s.as_str()).unwrap_or("flute4") {
        "fp16" => Backend::Dense,
        "flute2" => Backend::Flute { bits: 2 },
        "flute3" => Backend::Flute { bits: 3 },
        _ => Backend::Flute { bits: 4 },
    };
    let batch: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(1);

    let engine = Engine::new()?;
    let cfg = ModelConfig::load_named(engine.artifacts(), &cfg_name)?;
    let ckpt = engine.artifacts().join(format!("ckpt_{cfg_name}.bin"));
    anyhow::ensure!(ckpt.exists(), "run `higgs train --config {cfg_name}` first");
    let weights = Weights::load(&ckpt, cfg.clone())?;
    let registry =
        higgs::grids::registry::GridRegistry::with_disk_cache(engine.artifacts().join("grids"));
    let qmodel = match &backend {
        Backend::Dense => None,
        Backend::Flute { bits } => {
            let n = 1usize << (2 * bits);
            let q = HiggsQuantizer::new(registry.get(GridKind::Higgs, n, 2), cfg.group, 0x51);
            Some(QuantizedModel::quantize_all(&weights, &q))
        }
        _ => None,
    };
    drop(engine); // router builds its own client in-thread

    // open-loop trace: requests arrive over time
    let corpus = higgs::data::Corpus::new(cfg.vocab, cfg.seq, 1);
    let trace = generate_trace(
        &TraceConfig {
            n_requests: 16,
            prompt_len: (8, 24),
            max_new: (8, 16),
            mean_gap_ms: 20,
            seed: 7,
            ..Default::default()
        },
        &corpus,
    );

    let router = Router::spawn(
        cfg,
        RouterConfig { backend: backend.clone(), batch, ..Default::default() },
        weights,
        qmodel,
    );
    let t0 = std::time::Instant::now();
    for r in trace {
        let wait = r.arrival_ms.saturating_sub(t0.elapsed().as_millis() as u64);
        if wait > 0 {
            std::thread::sleep(std::time::Duration::from_millis(wait));
        }
        router.submit(r);
    }
    let mut done = 0;
    while done < 16 {
        match router.completions.recv_timeout(std::time::Duration::from_secs(120)) {
            Ok(c) => {
                println!(
                    "req {:>2}: {:>2} tokens in {:>7.1} ms  {:?}...",
                    c.id,
                    c.tokens.len(),
                    c.latency_ms,
                    &c.tokens[..c.tokens.len().min(6)]
                );
                done += 1;
            }
            Err(_) => break,
        }
    }
    let metrics = router.finish()?;
    println!("\n[{} batch={batch}] {}", backend.label(), metrics.summary());
    Ok(())
}
