//! END-TO-END driver: the full system on a real (synthetic-corpus)
//! workload, proving all three layers compose:
//!
//!   1. TRAIN a transformer from scratch (rust Adam over the AOT grad
//!      graph — L2/L1 under the hood) and log the loss curve;
//!   2. QUANTIZE it with HIGGS (uniform and dynamic §5 allocation);
//!   3. EVALUATE perplexity + in-context tasks before/after;
//!   4. SERVE batched requests through the FLUTE decode path (the
//!      Pallas LUT kernel) and report latency/throughput.
//!
//! Run: `cargo run --release --example e2e_pipeline` (~2 min; uses the
//! `tiny` config so it exercises everything quickly. Pass `base` for
//! the full-size run recorded in EXPERIMENTS.md.)

use higgs::config::ModelConfig;
use higgs::eval::Evaluator;
use higgs::grids::GridKind;
use higgs::linearity::calibrate::CalibMetric;
use higgs::model::Weights;
use higgs::quant::higgs::HiggsQuantizer;
use higgs::quant::QuantizedModel;
use higgs::runtime::Engine;
use higgs::serve::trace::{generate_trace, TraceConfig};
use higgs::serve::{Backend, GenerationEngine};
use higgs::train::Trainer;

fn main() -> anyhow::Result<()> {
    let cfg_name = std::env::args().nth(1).unwrap_or_else(|| "tiny".into());
    let steps: u64 = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(300);
    let engine = Engine::new()?;
    let cfg = ModelConfig::load_named(engine.artifacts(), &cfg_name)?;

    // ---- 1. train ----
    println!("== [1/4] training `{cfg_name}` for {steps} steps ==");
    let man = engine.load(&format!("grad_{cfg_name}"))?.manifest.clone();
    let mut weights = Weights::from_manifest(cfg.clone(), &man, Some(7))?;
    let trainer = Trainer::new(&engine, cfg.clone());
    let t0 = std::time::Instant::now();
    let report = trainer.train(&mut weights, steps, 4e-3, (steps / 10).max(1))?;
    println!(
        "loss {:.3} -> {:.3} in {:.1}s ({:.0} tok/s)",
        report.losses.first().unwrap().1,
        report.final_loss,
        t0.elapsed().as_secs_f64(),
        report.tokens_seen as f64 / t0.elapsed().as_secs_f64()
    );

    // ---- 2. quantize ----
    println!("\n== [2/4] quantizing (HIGGS p=2, 4 bits + dynamic 3.25) ==");
    let registry =
        higgs::grids::registry::GridRegistry::with_disk_cache(engine.artifacts().join("grids"));
    let q4 = HiggsQuantizer::new(registry.get(GridKind::Higgs, 256, 2), cfg.group, 0x51);
    let qm4 = QuantizedModel::quantize_all(&weights, &q4);
    println!("uniform: {:.2} bits/param", qm4.avg_bits());

    // ---- 3. evaluate ----
    println!("\n== [3/4] evaluation ==");
    let ev = Evaluator::new(&engine, cfg.clone());
    let ppl_fp = ev.perplexity(&weights)?;
    let s_fp = ev.task_scores(&weights, 3)?;
    let w4 = qm4.apply_to(&weights);
    let ppl_q4 = ev.perplexity(&w4)?;
    let s_q4 = ev.task_scores(&w4, 3)?;
    println!("fp32      : ppl {ppl_fp:.4}  tasks avg {:.3}", s_fp.average());
    println!("higgs 4.25: ppl {ppl_q4:.4}  tasks avg {:.3}", s_q4.average());
    anyhow::ensure!(
        ppl_q4 < ppl_fp * 1.25,
        "4-bit HIGGS should be near-lossless (got {ppl_q4} vs {ppl_fp})"
    );

    // dynamic allocation at 3.25 bits (data-free)
    let mut ev_cal = Evaluator::new(&engine, cfg.clone());
    ev_cal.ppl_batches = 1;
    let alphas = higgs::linearity::calibrate::calibrate_alphas(
        &ev_cal,
        &weights,
        &[0.08, 0.16, 0.24],
        CalibMetric::Kl,
        3,
    )?;
    let specs = [(16usize, 2usize), (64, 2), (256, 2)];
    let g_eff = cfg.group.min(cfg.d_model);
    let models: Vec<QuantizedModel> = specs
        .iter()
        .map(|&(n, p)| {
            let q = HiggsQuantizer::new(registry.get(GridKind::Higgs, n, p), cfg.group, 0x51);
            QuantizedModel::quantize_all(&weights, &q)
        })
        .collect();
    let layers = weights.linear_names();
    let dims: Vec<usize> = cfg.linear_shapes().iter().map(|(_, (k, n))| k * n).collect();
    let mut t2 = vec![vec![0.0; specs.len()]; layers.len()];
    for (j, qm) in models.iter().enumerate() {
        for (l, (_, e)) in qm.layer_errors(&weights).iter().enumerate() {
            t2[l][j] = *e;
        }
    }
    let db = higgs::alloc::ErrorDb {
        layers: layers.clone(),
        dims,
        choices: specs
            .iter()
            .map(|&(n, p)| higgs::alloc::GridChoice {
                id: format!("n{n}p{p}"),
                bits: higgs::grids::registry::effective_bits(n, p, g_eff),
            })
            .collect(),
        t2,
    };
    // budget: halfway between the 2- and 3-bit uniform tiers, so the
    // DP must mix them; the comparison baseline is the LOWER tier
    // (same-or-less budget than dynamic).
    let budget = 0.5 * (db.choices[0].bits + db.choices[1].bits);
    let sol = higgs::alloc::solve_dp(&db, &alphas, budget)?;
    let qm_dyn = QuantizedModel::from_layers(
        layers
            .iter()
            .enumerate()
            .map(|(l, n)| models[sol.choice[l]].get(n).unwrap().clone())
            .collect(),
    );
    let ppl_dyn = ev.perplexity(&qm_dyn.apply_to(&weights))?;
    let ppl_uni = ev.perplexity(&models[0].apply_to(&weights))?;
    println!(
        "uniform @{:.2} bits: ppl {ppl_uni:.4}",
        db.choices[0].bits
    );
    println!(
        "dynamic @{:.2} bits (budget {budget:.2}): ppl {ppl_dyn:.4}",
        sol.avg_bits
    );

    // ---- 4. serve ----
    println!("\n== [4/4] serving through the FLUTE (Pallas LUT) decode path ==");
    let corpus = higgs::data::Corpus::new(cfg.vocab, cfg.seq, 1);
    let trace = generate_trace(
        &TraceConfig {
            n_requests: 8,
            prompt_len: (6, 12),
            max_new: (6, 10),
            ..Default::default()
        },
        &corpus,
    );
    // batch size: use 1 for tiny (only b1 artifacts exported), 4 for base
    let batch = if cfg_name == "base" { 4 } else { 1 };
    let q2 = HiggsQuantizer::new(registry.get(GridKind::Higgs, 16, 2), cfg.group, 0x51);
    let qm_serve = QuantizedModel::quantize_all(&weights, &q2);
    let mut ge = GenerationEngine::new(
        &engine,
        cfg.clone(),
        Backend::Flute { bits: 2 },
        batch,
        &weights,
        Some(&qm_serve),
    )?;
    let m = ge.run_closed_loop(trace)?;
    println!("flute2 serving: {}", m.summary());
    anyhow::ensure!(m.completions.len() == 8, "not all requests completed");

    println!("\nE2E pipeline complete: train -> quantize -> eval -> serve all green.");
    Ok(())
}
