//! Sharded artifact cold start — the "one artifact, N processes" path
//! as a library consumer, runnable WITHOUT XLA artifacts (fixture
//! weights): quantize a mixed-precision tiny model, persist it as a
//! format-v2 artifact, then cold-start TWO shards through the lazy
//! `ArtifactReader` and verify (a) the shards partition the layer
//! list exactly, (b) each shard's ranged reads stay inside its own
//! plane byte budget, and (c) every shard-decoded dense plane is
//! bit-for-bit identical to the unsharded `QuantArtifact::load`.
//!
//! ```bash
//! cargo run --release --example shard_cold_start
//! ```

use higgs::grids::registry::GridRegistry;
use higgs::grids::GridKind;
use higgs::model::fixture;
use higgs::quant::artifact::QuantArtifact;
use higgs::quant::higgs::HiggsQuantizer;
use higgs::quant::reader::{ArtifactReader, ShardSpec};
use higgs::quant::{QuantizedModel, Quantizer};

fn main() -> anyhow::Result<()> {
    let w = fixture::tiny_weights(42);
    let reg = GridRegistry::new();

    // mixed model: alternate 2-bit and 4-bit HIGGS grids per layer
    let q2 = HiggsQuantizer::new(reg.get(GridKind::Higgs, 16, 2), 16, 0x51);
    let q4 = HiggsQuantizer::new(reg.get(GridKind::Higgs, 256, 2), 16, 0x51);
    let names = w.linear_names();
    let assignment: Vec<(String, &dyn Quantizer)> = names
        .iter()
        .enumerate()
        .map(|(i, n)| {
            let q: &dyn Quantizer = if i % 2 == 0 { &q2 } else { &q4 };
            (n.clone(), q)
        })
        .collect();
    let qm = QuantizedModel::quantize_mixed(&w, &assignment);
    let art = QuantArtifact::from_model("tiny", &qm);
    let path = std::env::temp_dir()
        .join(format!("higgs_shard_cold_start_{}.qa", std::process::id()));
    art.save(&path)?;
    let file_len = std::fs::metadata(&path)?.len();

    // the unsharded oracle
    let full = QuantArtifact::load(&path)?;

    let shards = [ShardSpec::parse("0/2")?, ShardSpec::parse("1/2")?];
    let mut covered: Vec<String> = Vec::new();
    for shard in &shards {
        // each shard is its own process in a real fleet: fresh reader,
        // fresh byte counter
        let reader = ArtifactReader::open(&path)?;
        let after_open = reader.bytes_read();
        let slice = reader.load_shard(shard)?;
        let stats = reader.shard_stats(shard);
        let plane_io = reader.bytes_read() - after_open;
        assert_eq!(
            plane_io, stats.plane_bytes,
            "shard {shard} read outside its plane byte ranges"
        );
        assert!(
            reader.bytes_read() < file_len,
            "shard {shard} cold start should not read the whole file"
        );
        let mut params = 0usize;
        for s in &slice.layers {
            let want = full.get(&s.name).expect("layer exists in full load");
            let (a, b) = (s.dequantize(), want.dequantize());
            assert!(
                a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits()),
                "shard {shard}: dense plane diverged for {}",
                s.name
            );
            params += s.k * s.n_out;
            covered.push(s.name.clone());
        }
        println!(
            "shard {shard}: {} of {} layers, {} plane bytes (of {} total), \
             {:.3} bits/param, {params} params decoded bit-exact",
            stats.layers,
            full.layers.len(),
            stats.plane_bytes,
            file_len,
            stats.bits_per_param,
        );
    }

    // the union of the shards is every layer exactly once
    let mut want: Vec<String> = full.layers.iter().map(|l| l.name.clone()).collect();
    covered.sort();
    want.sort();
    assert_eq!(covered, want, "shards must partition the layer list");
    println!("2-shard union covers all {} layers exactly once", want.len());

    std::fs::remove_file(&path)?;
    Ok(())
}
