//! Dynamic (non-uniform) quantization example — the §5 pipeline:
//! calibrate α data-free (KL on random tokens), measure per-layer grid
//! errors, solve the knapsack, and compare against uniform HIGGS at the
//! same budget.
//!
//! ```bash
//! ./target/release/higgs train --config tiny   # once
//! cargo run --release --example dynamic_quant -- tiny 3.25
//! ```

use higgs::experiments::{figures, ExpContext};
use higgs::linearity::calibrate::CalibMetric;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cfg_name = args.first().cloned().unwrap_or_else(|| "tiny".into());
    let budget: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(3.25);

    let ctx = ExpContext::load(&cfg_name)?;
    let ev = ctx.evaluator();
    println!("fp32: ppl {:.4}", ev.perplexity(&ctx.weights)?);

    // 1. data-free α calibration (KL on random tokens; cached on disk)
    let alphas = ctx.alphas(CalibMetric::Kl, 7)?;
    println!("\nper-layer sensitivities α (data-free KL calibration):");
    let mut sorted = alphas.alphas.clone();
    sorted.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    for (name, a) in sorted.iter().take(5) {
        println!("  {name:<14} α = {a:.4}   <- most sensitive");
    }

    // 2. per-layer error database over the FLUTE-supported grids
    //    (parallel over every (layer, choice) pair)
    let choices = figures::flute_choices(&ctx);
    let build = figures::build_error_db(&ctx, &choices)?;
    let db = &build.db;

    // 3. exact DP allocation at the budget
    let sol = higgs::alloc::solve_dp(db, &alphas, budget)?;
    println!("\nDP allocation at b_max = {budget}:");
    print!("{}", sol.describe(db));

    // 4. measured comparison vs uniform at the same budget
    let qm_dyn = build.realize(&sol.choice)?;
    let ppl_dyn = ev.perplexity(&qm_dyn.apply_to(&ctx.weights))?;
    // uniform = the single choice closest to the budget
    let uni_idx = db
        .best_uniform_choice(budget)
        .expect("budget below the cheapest registry grid");
    let qm_uni = build.realize_uniform(uni_idx)?;
    let ppl_uni = ev.perplexity(&qm_uni.apply_to(&ctx.weights))?;
    println!(
        "\nuniform {} ({:.2} bits): ppl {:.4}",
        db.choices[uni_idx].id,
        qm_uni.avg_bits(),
        ppl_uni
    );
    println!("dynamic ({:.2} bits):        ppl {:.4}", sol.avg_bits, ppl_dyn);
    println!(
        "dynamic HIGGS {} uniform at equal budget",
        if ppl_dyn <= ppl_uni { "beats/matches" } else { "LOST TO (unexpected)" }
    );
    Ok(())
}
