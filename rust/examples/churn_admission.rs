//! Continuous-batching churn, runnable WITHOUT XLA artifacts: drive the
//! real admission machinery (`plan_admissions` + `KvBlockManager` +
//! both KV state layouts) through a Poisson-ish arrival stream with
//! mixed prompt lengths and verify, in one process, the PR's three
//! acceptance claims:
//!
//!   1. slot-strided admission stays bit-identical to the full-splice
//!      reference through the whole run (checked after every mutation);
//!   2. admission bytes: strided moves one slot's K+V per admitted
//!      request — independent of the live batch — while the reference
//!      round-trips the whole cache per prefill;
//!   3. continuous batching admits into slots freed mid-batch and
//!      finishes the same workload in fewer decode steps than the
//!      drain-between-batches baseline.
//!
//! ```bash
//! cargo run --release --example churn_admission
//! ```

use higgs::serve::{run_churn, ChurnConfig, KvLayout, KvMode};

fn main() -> anyhow::Result<()> {
    let base = ChurnConfig {
        layout: KvLayout { layers: 2, heads: 2, seq: 48, d_head: 4 },
        batch: 4,
        n_requests: 32,
        prompt_len: (4, 12),
        long_frac: 0.25,
        long_prompt_len: (24, 40),
        max_new: (4, 12),
        mean_gap_steps: 1.5,
        reject_frac: 0.1,
        drain: false,
        mode: KvMode::Both,
        seed: 0x51,
    };

    // continuous batching, both layouts live and bit-compared after
    // every admission and decode swap
    let cont = run_churn(&base)?;
    assert_eq!(
        cont.completions + cont.rejected + cont.dropped,
        base.n_requests as u64,
        "request accounting leak"
    );
    assert_eq!(cont.blocks_leaked, 0, "KV blocks leaked");
    assert!(cont.mid_batch_admissions > 0, "no mid-batch admission under churn");
    assert_eq!(
        cont.admit_bytes_strided,
        cont.completions * base.layout.slot_kv_bytes(),
        "strided admission must move exactly one slot's K+V per admitted request"
    );
    assert_eq!(
        cont.admit_bytes_fullsplice,
        cont.prefills * 4 * base.layout.full_elems(base.batch) as u64 * 4,
        "reference admission must round-trip the whole cache per prefill"
    );
    println!(
        "continuous: {} completions ({} rejected), {} decode steps, \
         {} mid-batch admissions, queue peak {}",
        cont.completions, cont.rejected, cont.steps, cont.mid_batch_admissions, cont.queue_peak
    );
    println!(
        "admission bytes: strided {} vs full-splice {} ({}x)",
        cont.admit_bytes_strided,
        cont.admit_bytes_fullsplice,
        cont.admit_bytes_fullsplice / cont.admit_bytes_strided.max(1)
    );

    // the drain-between-batches baseline on the same workload
    let drain = run_churn(&ChurnConfig { drain: true, ..base.clone() })?;
    assert_eq!(drain.completions, cont.completions);
    assert_eq!(drain.total_generated, cont.total_generated);
    assert_eq!(drain.mid_batch_admissions, 0);
    assert!(
        cont.steps < drain.steps,
        "continuous ({}) must finish in fewer decode steps than drain ({})",
        cont.steps,
        drain.steps
    );
    println!(
        "drain baseline: {} decode steps for the same {} tokens \
         (continuous saves {:.0}%)",
        drain.steps,
        drain.total_generated,
        100.0 * (drain.steps - cont.steps) as f64 / drain.steps as f64
    );
    Ok(())
}
