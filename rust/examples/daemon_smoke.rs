//! Network serving daemon smoke, runnable WITHOUT XLA artifacts: start
//! `higgs serve-daemon`'s core (TCP accept loop + wire protocol + span
//! tracing) against the synthetic pipeline stack and verify, in one
//! process, the PR's acceptance claims:
//!
//!   1. the TCP front-end is transport, not policy: tokens streamed to
//!      loopback clients are bit-identical to a direct in-process run
//!      of the same requests through the pipeline coordinator;
//!   2. a corrupt client frame closes THAT connection, is counted in
//!      `internal_errors`/`wire_errors`, and the daemon keeps serving;
//!   3. graceful drain: late submits bounce as typed `Busy`, every
//!      admitted request completes, and the final report carries
//!      span-derived per-phase histograms.
//!
//! ```bash
//! cargo run --release --example daemon_smoke
//! ```

use higgs::serve::{
    run_pipeline, ClientOutcome, ClientRequest, Daemon, DaemonConfig, PipelineConfig,
    PipelineSource, Request,
};

fn main() -> anyhow::Result<()> {
    let pipeline = PipelineConfig { shards: 2, batch: 4, layers: 6, ..Default::default() };
    let reqs: Vec<ClientRequest> = (1..=6u64)
        .map(|id| ClientRequest {
            id,
            prompt: vec![id as i32, 2 * id as i32 + 1, 3],
            max_new: 3 + (id % 4) as u32,
            deadline_ms: 0,
        })
        .collect();

    // oracle: the same requests straight through the coordinator
    let arrivals: Vec<(u64, Request)> = reqs
        .iter()
        .map(|r| {
            (
                0u64,
                Request {
                    id: r.id,
                    prompt: r.prompt.clone(),
                    max_new: r.max_new as usize,
                    arrival_ms: 0,
                },
            )
        })
        .collect();
    let oracle = run_pipeline(&pipeline, &PipelineSource::Synthetic, arrivals)?;
    assert_eq!(oracle.completions.len(), reqs.len(), "oracle run dropped requests");

    // 1. loopback clients see the oracle's exact token streams
    let cfg = DaemonConfig { pipeline, ..Default::default() };
    let daemon = Daemon::start(cfg, PipelineSource::Synthetic)?;
    println!("daemon listening on {}", daemon.addr());
    let got = higgs::serve::request_many(daemon.addr(), &reqs)?;
    assert_eq!(got.len(), reqs.len());
    for (id, outcome) in &got {
        let want = &oracle
            .completions
            .iter()
            .find(|c| c.id == *id)
            .expect("oracle completion missing")
            .tokens;
        match outcome {
            ClientOutcome::Done { tokens, .. } => {
                assert_eq!(tokens, want, "request {id}: TCP tokens diverged from direct run");
            }
            other => anyhow::bail!("request {id} resolved to {other:?}"),
        }
    }
    println!("{} requests over TCP bit-identical to the direct pipeline run", got.len());

    // 2. a corrupt frame kills one connection, not the daemon
    {
        use std::io::{Read as _, Write as _};
        let mut s = std::net::TcpStream::connect(daemon.addr())?;
        s.write_all(&[0x13, 0x00, 0x00, 0x00, 0xde, 0xad, 0xbe, 0xef])?;
        s.shutdown(std::net::Shutdown::Write)?;
        let mut buf = [0u8; 8];
        let n = s.read(&mut buf).unwrap_or(0);
        assert_eq!(n, 0, "the daemon must close a corrupted connection");
    }
    let after = higgs::serve::request_many(
        daemon.addr(),
        &[ClientRequest { id: 99, prompt: vec![9, 9], max_new: 2, deadline_ms: 0 }],
    )?;
    assert!(
        matches!(after[0].1, ClientOutcome::Done { .. }),
        "the daemon must keep serving after a corrupt frame"
    );
    println!("corrupt frame: connection closed, daemon kept serving");

    // 3. graceful drain: the final report accounts for everything
    let rep = daemon.finish()?;
    assert_eq!(rep.completions.len(), reqs.len() + 1);
    assert_eq!(rep.wire_errors, 1, "the garbage burst must be counted");
    assert_eq!(rep.metrics.internal_errors, 1);
    assert!(!rep.metrics.phases.is_empty(), "span histograms missing from the report");
    assert_eq!(rep.spans.total() as usize, reqs.len() + 1);
    println!("[daemon n={} steps={}] {}", rep.shards, rep.steps, rep.metrics.summary());
    print!("{}", rep.metrics.phase_report());
    println!("drain: all admitted requests completed, report accounts for the wire error");
    Ok(())
}
