//! QuantArtifact roundtrip — the "quantize once, serve many times"
//! storage path as a library consumer, runnable WITHOUT XLA artifacts
//! (fixture weights): quantize a mixed-precision tiny model, persist
//! it as a self-describing artifact, cold-start reload it, and verify
//! the reload is bit-for-bit (packed planes, packed bits accounting,
//! dequantized tensors).
//!
//! ```bash
//! cargo run --release --example artifact_roundtrip
//! ```

use higgs::grids::registry::GridRegistry;
use higgs::grids::GridKind;
use higgs::model::{fixture, Manifest};
use higgs::quant::artifact::QuantArtifact;
use higgs::quant::higgs::HiggsQuantizer;
use higgs::quant::{QuantizedModel, Quantizer};

fn main() -> anyhow::Result<()> {
    let w = fixture::tiny_weights(42);
    let reg = GridRegistry::new();

    // mixed model: alternate 2-bit and 4-bit HIGGS grids per layer
    let q2 = HiggsQuantizer::new(reg.get(GridKind::Higgs, 16, 2), 16, 0x51);
    let q4 = HiggsQuantizer::new(reg.get(GridKind::Higgs, 256, 2), 16, 0x51);
    let names = w.linear_names();
    let assignment: Vec<(String, &dyn Quantizer)> = names
        .iter()
        .enumerate()
        .map(|(i, n)| {
            let q: &dyn Quantizer = if i % 2 == 0 { &q2 } else { &q4 };
            (n.clone(), q)
        })
        .collect();
    let qm = QuantizedModel::quantize_mixed(&w, &assignment);

    // snapshot → validate shapes against the dense manifest → persist
    let art = QuantArtifact::from_model("tiny", &qm);
    let man = Manifest::parse(&fixture::dense_manifest_text(&fixture::tiny_config()))?;
    art.validate_against(&man)?;
    let path = std::env::temp_dir()
        .join(format!("higgs_artifact_roundtrip_{}.qa", std::process::id()));
    art.save(&path)?;
    let on_disk = std::fs::metadata(&path)?.len();

    // cold-start reload: parse + checksum + full validation
    let loaded = QuantArtifact::load(&path)?;
    let back = loaded.to_model()?;
    let mut checked = 0usize;
    for (a, b) in qm.layers.iter().zip(&back.layers) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.spec, b.spec, "spec diverged for {}", a.name);
        assert_eq!(a.packed_codes(), b.packed_codes(), "packed plane diverged for {}", a.name);
        let (da, db) = (a.dequantize(), b.dequantize());
        assert!(
            da.data.iter().zip(&db.data).all(|(x, y)| x.to_bits() == y.to_bits()),
            "dequantize diverged for {}",
            a.name
        );
        checked += 1;
    }
    assert_eq!(qm.packed_avg_bits().to_bits(), back.packed_avg_bits().to_bits());
    println!(
        "{checked} layers roundtripped bit-for-bit; {:.3} bits/param packed, {} bytes on disk",
        loaded.packed_avg_bits(),
        on_disk
    );

    // the serving cold-start path: decode every layer STRAIGHT from
    // the bit-packed planes (no unpacked code plane, no dense cache)
    let mut decoded = 0usize;
    for s in &loaded.layers {
        decoded += s.dequantize().len();
    }
    println!("cold-start decode-from-packed OK ({decoded} weights)");

    std::fs::remove_file(&path)?;
    Ok(())
}
