//! Pipeline-parallel serving smoke, runnable WITHOUT XLA artifacts:
//! stream activation frames coordinator → shard 0 → … → shard N−1 →
//! coordinator over the in-process `LocalPipe` transport and verify,
//! in one process, the PR's acceptance claims:
//!
//!   1. sharding is an execution strategy, not a model change: token
//!      streams are bit-identical at 1/2/4 shards for any micro-batch
//!      depth;
//!   2. per-shard KV: each worker holds layers/N of the stack, so the
//!      deepest shard's resident KV is exactly 1/N of the
//!      single-process cache and the total is conserved;
//!   3. micro-batching fills the ring: K > 1 in-flight micro-batches
//!      shrink the coordinator-measured pipeline bubble vs K = 1;
//!   4. a corrupted frame surfaces as `Err` + `internal_errors` —
//!      never a panic, never a wedged ring.
//!
//! ```bash
//! cargo run --release --example pipeline_smoke
//! ```

use higgs::serve::churn::churn_arrivals;
use higgs::serve::{
    run_pipeline, ChurnConfig, PipelineConfig, PipelineCoordinator, PipelineSource, Request,
};

fn main() -> anyhow::Result<()> {
    let mk = |shards: usize, k: usize| PipelineConfig {
        shards,
        micro_batches: k,
        batch: 4,
        layers: 8,
        ..Default::default()
    };
    let workload = ChurnConfig { n_requests: 24, ..Default::default() };
    let src = PipelineSource::Synthetic;

    // 1. bit-identity across shard counts and micro-batch depths
    let oracle = run_pipeline(&mk(1, 1), &src, churn_arrivals(&workload))?;
    assert!(!oracle.completions.is_empty(), "oracle run generated nothing");
    for (shards, k) in [(2usize, 1usize), (2, 4), (4, 2)] {
        let rep = run_pipeline(&mk(shards, k), &src, churn_arrivals(&workload))?;
        assert_eq!(rep.completions.len(), oracle.completions.len());
        for (a, b) in oracle.completions.iter().zip(&rep.completions) {
            assert_eq!(a.id, b.id, "completion order diverged at n={shards} k={k}");
            assert_eq!(a.tokens, b.tokens, "tokens diverged at n={shards} k={k}");
        }
        assert_eq!(rep.blocks_leaked, 0, "KV blocks leaked");
        println!(
            "n={shards} k={k}: {} completions bit-identical to single-process, \
             {} frames / {} wire bytes, bubble {:.2} ms",
            rep.completions.len(),
            rep.total_frames(),
            rep.total_wire_bytes(),
            rep.metrics.pipeline_bubble_ms
        );
    }

    // 2. per-shard KV accounting: the split conserves the cache and
    // each worker holds exactly 1/N of it
    let four = run_pipeline(&mk(4, 2), &src, churn_arrivals(&workload))?;
    let kv1 = oracle.worker_reports[0].kv_bytes;
    let kv4: u64 = four.worker_reports.iter().map(|w| w.kv_bytes).sum();
    assert_eq!(kv1, kv4, "total KV bytes must be conserved across the split");
    for w in &four.worker_reports {
        assert_eq!(w.kv_bytes, kv1 / 4, "per-shard KV must be 1/N of the model's");
    }
    println!(
        "per-shard KV: {} bytes per worker x4 == {} single-process bytes",
        kv1 / 4,
        kv1
    );

    // 3. deeper micro-batching shrinks the pipeline bubble
    let k1 = run_pipeline(&mk(4, 1), &src, churn_arrivals(&workload))?;
    let k4 = run_pipeline(&mk(4, 4), &src, churn_arrivals(&workload))?;
    assert!(
        k4.metrics.pipeline_bubble_ms < k1.metrics.pipeline_bubble_ms,
        "K=4 bubble ({:.2} ms) must undercut K=1 ({:.2} ms)",
        k4.metrics.pipeline_bubble_ms,
        k1.metrics.pipeline_bubble_ms
    );
    println!(
        "bubble at 4 shards: K=1 {:.2} ms -> K=4 {:.2} ms",
        k1.metrics.pipeline_bubble_ms, k4.metrics.pipeline_bubble_ms
    );

    // 4. corruption is an error, not a panic, and the ring still drains
    let mut pc = PipelineCoordinator::new(mk(2, 1), &src)?;
    pc.submit(Request { id: 1, prompt: vec![3, 1, 4], max_new: 4, arrival_ms: 0 });
    pc.inject_raw_downstream(vec![0xde, 0xad, 0xbe, 0xef, 9, 9])?;
    assert!(pc.tick().is_err(), "a corrupt frame must surface as Err");
    let rep = pc.finish()?;
    assert!(rep.metrics.internal_errors >= 1, "corruption must be counted");
    println!(
        "corrupt frame: Err surfaced, {} internal error(s), ring drained clean",
        rep.metrics.internal_errors
    );
    Ok(())
}
