//! Quickstart: quantize a trained model with HIGGS and compare against
//! NF/AF — the paper's headline comparison in ~40 lines of API use.
//!
//! ```bash
//! make artifacts && cargo build --release
//! ./target/release/higgs train --config tiny --steps 300   # once
//! cargo run --release --example quickstart
//! ```

use higgs::config::ModelConfig;
use higgs::eval::Evaluator;
use higgs::grids::registry::GridRegistry;
use higgs::grids::GridKind;
use higgs::model::Weights;
use higgs::quant::higgs::HiggsQuantizer;
use higgs::quant::lut::LutQuantizer;
use higgs::quant::{QuantizedModel, Quantizer};
use higgs::runtime::Engine;

fn main() -> anyhow::Result<()> {
    // 1. load the runtime + a trained checkpoint
    let engine = Engine::new()?;
    let cfg_name = std::env::args().nth(1).unwrap_or_else(|| "tiny".into());
    let cfg = ModelConfig::load_named(engine.artifacts(), &cfg_name)?;
    let ckpt = engine.artifacts().join(format!("ckpt_{cfg_name}.bin"));
    anyhow::ensure!(ckpt.exists(), "run `higgs train --config {cfg_name}` first");
    let weights = Weights::load(&ckpt, cfg.clone())?;
    let ev = Evaluator::new(&engine, cfg.clone());
    println!("fp32 baseline: ppl {:.4}", ev.perplexity(&weights)?);

    // 2. quantize with three grids at the same ~4.25-bit budget
    let reg = GridRegistry::with_disk_cache(engine.artifacts().join("grids"));
    let methods: Vec<(&str, Box<dyn Quantizer>)> = vec![
        ("NF4", Box::new(LutQuantizer::new(reg.get(GridKind::Nf, 16, 1), cfg.group))),
        ("AF4", Box::new(LutQuantizer::new(reg.get(GridKind::Af, 16, 1), cfg.group))),
        (
            "HIGGS p=2",
            Box::new(HiggsQuantizer::new(reg.get(GridKind::Higgs, 256, 2), cfg.group, 0x51)),
        ),
    ];
    for (name, q) in methods {
        let qm = QuantizedModel::quantize_all(&weights, q.as_ref());
        let ppl = ev.perplexity(&qm.apply_to(&weights))?;
        let t2: f64 = qm.layer_errors(&weights).iter().map(|(_, e)| e).sum::<f64>()
            / qm.layers.len() as f64;
        println!(
            "{name:<10} {:.2} bits/param   mean t² {:.5}   ppl {:.4}",
            qm.avg_bits(),
            t2,
            ppl
        );
    }
    println!("\nHIGGS should have the lowest t² and PPL — the paper's claim.");
    Ok(())
}
