//! Print the crate's lock-rank graph as JSON and assert it is acyclic,
//! runnable WITHOUT XLA artifacts — this is the static half of the
//! concurrency audit turned inside out: instead of hunting violations,
//! it publishes the rank table and every static acquisition edge the
//! call-graph pass can see, so a reviewer (or CI log reader) can check
//! the serve stack's lock hierarchy at a glance.
//!
//! ```bash
//! cargo run --release --example lock_graph_smoke
//! ```

use higgs::audit::{graph, scan_tree};
use std::path::PathBuf;

fn main() -> anyhow::Result<()> {
    let src_root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");
    let scans = scan_tree(&src_root)?;
    let analysis = graph::analyze(&scans);
    print!("{}", graph::lock_graph_json(&analysis.graph));

    anyhow::ensure!(
        !analysis.graph.mutexes.is_empty(),
        "no ranked mutexes found — the serve stack should declare at least planes/reader/transport"
    );
    anyhow::ensure!(
        graph::is_acyclic(&analysis.graph),
        "lock-rank graph has a cycle — a static deadlock candidate"
    );
    let mut last = 0u32;
    for m in &analysis.graph.mutexes {
        anyhow::ensure!(last <= m.rank, "mutex list not sorted by rank");
        last = m.rank;
    }
    eprintln!(
        "lock_graph_smoke: OK — {} ranked mutex(es), {} acquisition edge(s), acyclic",
        analysis.graph.mutexes.len(),
        analysis.graph.edges.len()
    );
    Ok(())
}
