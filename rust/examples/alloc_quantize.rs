//! Mixed-precision pipeline example — the §5 problem (5) end-to-end as
//! a library consumer: measure the per-layer error database, solve the
//! DP under a bit budget, REALIZE the allocation as a mixed-precision
//! model (every layer its own grid/bits/packing), verify the budget
//! against BIT-EXACT packed sizes, and serve it through
//! `Backend::Mixed`.
//!
//! ```bash
//! ./target/release/higgs train --config tiny   # once
//! cargo run --release --example alloc_quantize -- tiny 3.25
//! ```

use higgs::alloc::errordb::build_error_db;
use higgs::alloc::solve_dp;
use higgs::experiments::{figures, ExpContext};
use higgs::linearity::calibrate::CalibMetric;
use higgs::linearity::predict::predict_penalty;
use higgs::serve::trace::{generate_trace, TraceConfig};
use higgs::serve::{Backend, GenerationEngine};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cfg_name = args.first().cloned().unwrap_or_else(|| "tiny".into());
    let budget: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(3.25);
    let ctx = ExpContext::load(&cfg_name)?;

    // 1. sensitivities (data-free KL; cached under artifacts/)
    let alphas = ctx.alphas(CalibMetric::Kl, 7)?;

    // 2. error database: every (layer, registry grid choice) pair,
    //    parallel over the flattened task list
    let choices = figures::flute_choices(&ctx);
    let t0 = std::time::Instant::now();
    let build = build_error_db(&ctx.weights, &choices)?;
    println!(
        "error db: {} layers x {} choices in {:.2}s",
        build.db.layers.len(),
        build.db.choices.len(),
        t0.elapsed().as_secs_f64()
    );

    // 3. exact DP under the budget + mixed-precision realization
    let sol = solve_dp(&build.db, &alphas, budget)?;
    print!("{}", sol.describe(&build.db));
    let qm = build.realize(&sol.choice)?;
    println!(
        "packed: {:.3} bits/param (bit-exact) under budget {budget}",
        qm.packed_avg_bits()
    );

    // 4. linearity-theorem check: predicted vs measured penalty
    let measured = predict_penalty(&alphas, &qm.layer_errors(&ctx.weights));
    println!(
        "penalty: predicted {:.6}, measured {:.6}",
        sol.predicted_penalty, measured
    );

    // 5. serve the mixed model (dense decode on per-layer dequantized
    //    weights — the LUT kernels need one global grid, a mixed model
    //    has many)
    let corpus = higgs::data::Corpus::new(ctx.cfg.vocab, ctx.cfg.seq, 1);
    let trace = generate_trace(
        &TraceConfig { n_requests: 4, max_new: (4, 8), ..Default::default() },
        &corpus,
    );
    let mut ge = GenerationEngine::new(
        &ctx.engine,
        ctx.cfg.clone(),
        Backend::Mixed,
        1,
        &ctx.weights,
        Some(&qm),
    )?;
    let m = ge.run_closed_loop(trace)?;
    println!("[mixed b=1] {}", m.summary());
    Ok(())
}
