//! Offline stub of the `xla` (xla-rs / PJRT) binding surface the
//! `higgs` runtime uses.
//!
//! The real crate links libxla + the PJRT CPU plugin, which is not part
//! of the offline toolchain. This stub keeps the whole workspace
//! compiling and lets everything that does NOT execute an HLO artifact
//! (quantizers, grids, allocation, serving accounting, benches of the
//! pure-rust hot paths) run normally. Host-side `Literal` plumbing
//! (`vec1`, `reshape`, `to_vec`) genuinely works; the first call that
//! would need the PJRT runtime (`HloModuleProto::from_text_file`,
//! `compile`, `execute*`) returns an error naming the stub, which the
//! artifact-gated tests and CLI paths surface cleanly.

use std::fmt;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT runtime is not available in this build (stub `xla` crate; \
         link the real xla-rs bindings to execute HLO artifacts)"
    ))
}

/// Untyped element storage (implementation detail of [`Literal`]).
#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum Raw {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Raw {
    fn len(&self) -> usize {
        match self {
            Raw::F32(v) => v.len(),
            Raw::I32(v) => v.len(),
        }
    }
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Sized + Clone {
    #[doc(hidden)]
    fn wrap(v: Vec<Self>) -> Raw;
    #[doc(hidden)]
    fn unwrap(raw: &Raw) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Raw {
        Raw::F32(v)
    }
    fn unwrap(raw: &Raw) -> Option<Vec<Self>> {
        match raw {
            Raw::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Raw {
        Raw::I32(v)
    }
    fn unwrap(raw: &Raw) -> Option<Vec<Self>> {
        match raw {
            Raw::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Host-side tensor literal (data + dims). Fully functional in the stub.
#[derive(Debug, Clone)]
pub struct Literal {
    raw: Raw,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { raw: T::wrap(v.to_vec()), dims: vec![v.len() as i64] }
    }

    /// Reshape (element count checked; empty dims = scalar).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.raw.len() as i64;
        if want != have {
            return Err(Error(format!("reshape: {have} elements into shape {dims:?}")));
        }
        Ok(Literal { raw: self.raw.clone(), dims: dims.to_vec() })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Copy the elements out as `Vec<T>`; errors on dtype mismatch.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.raw).ok_or_else(|| Error("to_vec: literal dtype mismatch".to_string()))
    }

    /// Decompose a tuple literal — only execution produces tuples, so
    /// the stub can never have one.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module (opaque; never constructible in the stub).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-resident buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// PJRT client handle. `cpu()` succeeds so engines can be constructed
/// (and non-executing paths exercised); `compile`/upload fail.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient(()))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_literal"))
    }
}

/// Compiled executable handle (never constructible in the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }

    pub fn execute_b<T: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3]).is_err());
        // scalar reshape
        let s = Literal::vec1(&[7i32]).reshape(&[]).unwrap();
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn runtime_paths_error() {
        let client = PjRtClient::cpu().unwrap();
        let lit = Literal::vec1(&[0.0f32]);
        assert!(client.buffer_from_host_literal(None, &lit).is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(lit.to_tuple().is_err());
    }
}
