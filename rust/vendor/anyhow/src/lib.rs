//! Minimal, dependency-free reimplementation of the `anyhow` API surface
//! this workspace uses: `Error`, `Result`, `Context`, and the
//! `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Semantics mirror upstream anyhow where it matters here:
//! * `Display` shows the outermost message; `{:#}` appends the cause
//!   chain as `outer: inner: ...`;
//! * `Debug` shows the message plus a `Caused by:` list;
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`;
//! * `.context(..)` / `.with_context(..)` work on both `Result<_, E>`
//!   (E a std error) and `Result<_, anyhow::Error>` and `Option<_>`.
//!
//! Like upstream, `Error` deliberately does NOT implement
//! `std::error::Error` — that is what makes the blanket `From` impl
//! coherent.

use std::fmt::{self, Display};

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A boxed-up error: message plus an optional cause chain.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: Display>(self, context: C) -> Self {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate the cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut msgs = vec![self.msg.as_str()];
        let mut cur = &self.source;
        while let Some(e) = cur {
            msgs.push(e.msg.as_str());
            cur = &e.source;
        }
        msgs.into_iter()
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur = &self.source;
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = &e.source;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if self.source.is_some() {
            f.write_str("\n\nCaused by:")?;
            let mut cur = &self.source;
            while let Some(e) = cur {
                write!(f, "\n    {}", e.msg)?;
                cur = &e.source;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Flatten the std source chain into nested Errors.
        let mut msgs: Vec<String> = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut nested: Option<Box<Error>> = None;
        for m in msgs.into_iter().rev() {
            nested = Some(Box::new(Error { msg: m, source: nested }));
        }
        Error { msg: e.to_string(), source: nested }
    }
}

mod ext {
    use super::Error;

    /// Anything that can become an `Error` — std errors via the blanket
    /// impl, plus `Error` itself. (Coherent because `Error` does not
    /// implement `std::error::Error`.)
    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> Error {
            Error::from(self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// Context extension for `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ext::IntoError> Context<T, E> for std::result::Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 42);
    }

    #[test]
    fn display_and_chain() {
        let e = fails().unwrap_err().context("outer");
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: boom 42");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn std_error_converts() {
        let r: Result<i32> = "nope".parse::<i32>().map_err(Error::from);
        assert!(r.is_err());
        let r2: Result<i32> = "nope".parse::<i32>().context("parsing");
        assert_eq!(r2.unwrap_err().to_string(), "parsing");
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
        let v = Some(3).with_context(|| "unused").unwrap();
        assert_eq!(v, 3);
    }

    #[test]
    fn ensure_macro() {
        fn check(x: i32) -> Result<()> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(())
        }
        assert!(check(1).is_ok());
        assert!(check(-1).unwrap_err().to_string().contains("positive"));
    }
}
