//! Minimal stand-in for the `log` facade macros used in this workspace.
//!
//! Records go to stderr when the `HIGGS_LOG` environment variable is
//! set (any value); otherwise they are formatted and dropped. No
//! levels/filtering beyond that — the workspace only uses
//! `info!`/`debug!` on cold paths.

/// Emit one record (macro plumbing; not meant to be called directly).
pub fn __emit(level: &str, args: std::fmt::Arguments<'_>) {
    if std::env::var_os("HIGGS_LOG").is_some() {
        eprintln!("[{level}] {args}");
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::__emit("ERROR", ::std::format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::__emit("WARN", ::std::format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::__emit("INFO", ::std::format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::__emit("DEBUG", ::std::format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { $crate::__emit("TRACE", ::std::format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    #[test]
    fn macros_expand() {
        // must compile and not panic with or without HIGGS_LOG
        info!("hello {}", 1);
        debug!("x = {x}", x = 2);
        warn!("w");
        error!("e");
        trace!("t");
    }
}
